//! The kernel population: every submitted individual, its lineage, and
//! its benchmark results.
//!
//! The paper's Evolutionary Selector sees "the members of the
//! population ... identified by an ID, and the IDs of each of their
//! 'parents' ..., as well as the benchmark results for 6 specified
//! MxKxN input configurations" (§3.1). This module is exactly that
//! ledger, plus lineage queries (ancestors, divergence points,
//! per-config winners) and JSONL persistence so a run can resume.
//!
//! Since the archive-scaling pass (§Perf, `benches/archive_scaling.rs`)
//! the population is an **indexed archive**: every query the planning
//! loop issues per round — `by_id`, `best`, the leaderboard top-k,
//! per-config winners, ancestor walks, duplicate probes — answers from
//! indexes maintained incrementally at [`Population::add`], in O(1) /
//! O(result) instead of re-scanning or re-sorting the member list.
//! All indexes preserve the exact tie-break order of the scan-based
//! implementation (first-minimum wins; equal scores keep insertion
//! order, as a stable sort would), so trajectories are bit-identical —
//! `tests/prop_invariants.rs` checks observational equivalence against
//! a naive reference on randomized archives.

use std::collections::{BTreeSet, HashMap};

use crate::genome::KernelGenome;
use crate::metrics::geomean;
use crate::util::json::{self, Json};
use crate::workload::GemmConfig;

/// Outcome of one submission, as the platform reported it.
#[derive(Debug, Clone, PartialEq)]
pub enum EvalOutcome {
    /// Correct kernel with per-config timings (microseconds), in the
    /// feedback suite's config order.
    Timings(Vec<f64>),
    /// Rejected before running (compile/launch failure) with reason.
    CompileFailure(String),
    /// Ran but produced wrong results.
    IncorrectResult(String),
    /// The backend cannot evaluate this genome at all (permanent, like
    /// a compile failure, but a distinct stable kind — the retry policy
    /// and the journal must tell them apart, DESIGN.md §14).
    Unsupported(String),
    /// The evaluation service errored transiently (injected by the
    /// fault model, DESIGN.md §14): retryable, never cached, never an
    /// archive result.
    TransientFailure(String),
    /// The evaluation lane died mid-run: the submission is lost;
    /// retryable on another lane.
    LaneFailure(String),
    /// Timings flagged as outliers by repeat-measure confirmation:
    /// retryable, never enter the archive as real measurements.
    SuspectTimings(Vec<f64>),
}

impl EvalOutcome {
    pub fn timings(&self) -> Option<&[f64]> {
        match self {
            EvalOutcome::Timings(t) => Some(t),
            _ => None,
        }
    }

    pub fn is_success(&self) -> bool {
        matches!(self, EvalOutcome::Timings(_))
    }

    /// Fault-class outcomes (DESIGN.md §14): transient service-side
    /// failures the recovery layer may retry. Never inserted into the
    /// eval cache, never published to the federation archive, never
    /// reconstructed into the cache on resume — a retry must genuinely
    /// re-evaluate.
    pub fn is_fault(&self) -> bool {
        matches!(
            self,
            EvalOutcome::TransientFailure(_)
                | EvalOutcome::LaneFailure(_)
                | EvalOutcome::SuspectTimings(_)
        )
    }

    pub fn to_json(&self) -> Json {
        match self {
            EvalOutcome::Timings(t) => Json::obj(vec![
                ("kind", Json::Str("timings".into())),
                ("us", Json::Arr(t.iter().map(|&x| Json::Num(x)).collect())),
            ]),
            EvalOutcome::CompileFailure(msg) => Json::obj(vec![
                ("kind", Json::Str("compile_failure".into())),
                ("msg", Json::Str(msg.clone())),
            ]),
            EvalOutcome::IncorrectResult(msg) => Json::obj(vec![
                ("kind", Json::Str("incorrect_result".into())),
                ("msg", Json::Str(msg.clone())),
            ]),
            EvalOutcome::Unsupported(msg) => Json::obj(vec![
                ("kind", Json::Str("unsupported".into())),
                ("msg", Json::Str(msg.clone())),
            ]),
            EvalOutcome::TransientFailure(msg) => Json::obj(vec![
                ("kind", Json::Str("transient_failure".into())),
                ("msg", Json::Str(msg.clone())),
            ]),
            EvalOutcome::LaneFailure(msg) => Json::obj(vec![
                ("kind", Json::Str("lane_failure".into())),
                ("msg", Json::Str(msg.clone())),
            ]),
            EvalOutcome::SuspectTimings(t) => Json::obj(vec![
                ("kind", Json::Str("suspect_timings".into())),
                ("us", Json::Arr(t.iter().map(|&x| Json::Num(x)).collect())),
            ]),
        }
    }

    /// Stream the [`Self::to_json`] object into `out`, byte-identical
    /// to `self.to_json().to_string()` (journal hot path, §Perf).
    pub fn write_json(&self, out: &mut String) {
        let timing_obj = |out: &mut String, kind: &str, t: &[f64]| {
            out.push_str("{\"kind\":\"");
            out.push_str(kind);
            out.push_str("\",\"us\":[");
            for (i, &x) in t.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                json::push_num_value(out, x);
            }
            out.push_str("]}");
        };
        let msg_obj = |out: &mut String, kind: &str, msg: &str| {
            out.push_str("{\"kind\":\"");
            out.push_str(kind);
            out.push_str("\",\"msg\":");
            json::push_str_value(out, msg);
            out.push('}');
        };
        match self {
            EvalOutcome::Timings(t) => timing_obj(out, "timings", t),
            EvalOutcome::SuspectTimings(t) => timing_obj(out, "suspect_timings", t),
            EvalOutcome::CompileFailure(msg) => msg_obj(out, "compile_failure", msg),
            EvalOutcome::IncorrectResult(msg) => msg_obj(out, "incorrect_result", msg),
            EvalOutcome::Unsupported(msg) => msg_obj(out, "unsupported", msg),
            EvalOutcome::TransientFailure(msg) => msg_obj(out, "transient_failure", msg),
            EvalOutcome::LaneFailure(msg) => msg_obj(out, "lane_failure", msg),
        }
    }

    pub fn from_json(o: &Json) -> Result<EvalOutcome, String> {
        let us = |o: &Json| -> Result<Vec<f64>, String> {
            o.get("us")
                .and_then(|x| x.as_arr())
                .ok_or("missing us")?
                .iter()
                .map(|x| x.as_f64().ok_or_else(|| "bad timing".to_string()))
                .collect()
        };
        let msg = |o: &Json| o.get("msg").and_then(|x| x.as_str()).unwrap_or("").to_string();
        Ok(match o.get("kind").and_then(|x| x.as_str()) {
            Some("timings") => EvalOutcome::Timings(us(o)?),
            Some("suspect_timings") => EvalOutcome::SuspectTimings(us(o)?),
            Some("compile_failure") => EvalOutcome::CompileFailure(msg(o)),
            Some("incorrect_result") => EvalOutcome::IncorrectResult(msg(o)),
            Some("unsupported") => EvalOutcome::Unsupported(msg(o)),
            Some("transient_failure") => EvalOutcome::TransientFailure(msg(o)),
            Some("lane_failure") => EvalOutcome::LaneFailure(msg(o)),
            _ => return Err("bad outcome kind".into()),
        })
    }
}

/// One member of the population.
#[derive(Debug, Clone, PartialEq)]
pub struct Individual {
    /// Zero-padded sequential id ("00001"), as in App. A.1.
    pub id: String,
    /// Parent ids: `[base]` or `[base, reference]`; empty for seeds.
    pub parents: Vec<String>,
    pub genome: KernelGenome,
    /// The experiment description that led to this kernel (seeds carry
    /// their provenance instead).
    pub experiment: String,
    /// The Kernel Writer's self-report of techniques actually applied.
    pub report: String,
    pub outcome: EvalOutcome,
}

impl Individual {
    /// Geomean of the feedback timings (None for failed submissions).
    pub fn score(&self) -> Option<f64> {
        self.outcome.timings().map(geomean)
    }

    pub fn to_json(&self) -> Json {
        let outcome = self.outcome.to_json();
        Json::obj(vec![
            ("id", Json::Str(self.id.clone())),
            (
                "parents",
                Json::Arr(self.parents.iter().map(|p| Json::Str(p.clone())).collect()),
            ),
            ("genome", self.genome.to_json()),
            ("experiment", Json::Str(self.experiment.clone())),
            ("report", Json::Str(self.report.clone())),
            ("outcome", outcome),
        ])
    }

    /// Stream the [`Self::to_json`] object into `out`, byte-identical
    /// to `self.to_json().to_string()` (keys in the emitter's sorted
    /// order) but with no intermediate tree or per-field `String` —
    /// the run-store journal's hot path (§Perf).
    pub fn write_json(&self, out: &mut String) {
        out.push_str("{\"experiment\":");
        json::push_str_value(out, &self.experiment);
        out.push_str(",\"genome\":");
        self.genome.write_json(out);
        out.push_str(",\"id\":");
        json::push_str_value(out, &self.id);
        out.push_str(",\"outcome\":");
        self.outcome.write_json(out);
        out.push_str(",\"parents\":[");
        for (i, p) in self.parents.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            json::push_str_value(out, p);
        }
        out.push_str("],\"report\":");
        json::push_str_value(out, &self.report);
        out.push('}');
    }

    pub fn from_json(v: &Json) -> Result<Individual, String> {
        let id = v
            .get("id")
            .and_then(|x| x.as_str())
            .ok_or("missing id")?
            .to_string();
        let parents = v
            .get("parents")
            .and_then(|x| x.as_arr())
            .ok_or("missing parents")?
            .iter()
            .map(|p| p.as_str().map(String::from).ok_or("bad parent id"))
            .collect::<Result<Vec<_>, _>>()?;
        let genome = KernelGenome::from_json(v.get("genome").ok_or("missing genome")?)?;
        let experiment = v
            .get("experiment")
            .and_then(|x| x.as_str())
            .unwrap_or("")
            .to_string();
        let report = v
            .get("report")
            .and_then(|x| x.as_str())
            .unwrap_or("")
            .to_string();
        let o = v.get("outcome").ok_or("missing outcome")?;
        let outcome = EvalOutcome::from_json(o)?;
        Ok(Individual {
            id,
            parents,
            genome,
            experiment,
            report,
            outcome,
        })
    }
}

/// The growing list of kernels (paper Fig. 1, right side), behind the
/// incrementally maintained indexes described in the module docs.
#[derive(Debug, Clone, Default)]
pub struct Population {
    members: Vec<Individual>,
    /// The feedback configs the timing vectors are indexed by.
    pub feedback_configs: Vec<GemmConfig>,
    /// id → member index: O(1) `by_id`, and what every lineage walk
    /// resolves parent ids through.
    index_by_id: HashMap<String, u32>,
    /// Genome content-hash → index of the FIRST member carrying it
    /// (insertion order), so the duplicate probe's positive path never
    /// re-renders fingerprints (§Perf). Positive hits are confirmed
    /// with genome equality — hash collisions cannot perturb dedup.
    index_by_fp: HashMap<u64, u32>,
    /// Per-member feedback geomean, computed once at `add` (`None` for
    /// failures) — queries never recompute it.
    scores: Vec<Option<f64>>,
    /// First parent resolved to a member index at `add` (`None` for
    /// seeds and dangling references). Resolution happens against the
    /// members already present, so `parent_index[i] < i` always: the
    /// ancestor walk strictly descends and needs no cycle guard (this
    /// replaces the old O(chain²) `out.iter().any` check).
    parent_index: Vec<Option<u32>>,
    /// Successful member indices in insertion order (what the old
    /// `successful()` scan produced).
    successful_order: Vec<u32>,
    /// Successful members as (total-order score key, index), iterating
    /// in (geomean asc, insertion asc) order — exactly what a stable
    /// sort of `successful()` by score yields. O(log n) insertion at
    /// `add`, so even a 100k-entry journal rebuild stays loglinear.
    leaderboard: BTreeSet<(u64, u32)>,
    /// Per feedback config: successful members as (total-order timing
    /// key, index). Answers "who beats timing t on config i" (the
    /// selector's specialist query) as a range scan in O(result).
    config_index: Vec<BTreeSet<(u64, u32)>>,
    /// Per feedback config: current winner (index, timing), first
    /// strictly-lower timing wins — the old scan's tie-break.
    winners: Vec<Option<(u32, f64)>>,
}

/// Total-order-preserving u64 encoding of an f64 — the IEEE-754 trick
/// behind [`f64::total_cmp`]: `key(a) < key(b)` iff `a.total_cmp(&b)`
/// is `Less`. Lets the score/timing indexes live in ordinary
/// `BTreeSet<(u64, u32)>`s (f64 itself is not `Ord`).
fn total_order_key(t: f64) -> u64 {
    let b = t.to_bits();
    if b >> 63 == 0 {
        b | 0x8000_0000_0000_0000
    } else {
        !b
    }
}

/// Inverse of [`total_order_key`] (a bijection on bit patterns).
fn total_order_decode(k: u64) -> f64 {
    f64::from_bits(if k >> 63 == 1 {
        k & 0x7FFF_FFFF_FFFF_FFFF
    } else {
        !k
    })
}

impl Population {
    pub fn new(feedback_configs: Vec<GemmConfig>) -> Self {
        let n = feedback_configs.len();
        Population {
            members: Vec::new(),
            feedback_configs,
            index_by_id: HashMap::new(),
            index_by_fp: HashMap::new(),
            scores: Vec::new(),
            parent_index: Vec::new(),
            successful_order: Vec::new(),
            leaderboard: BTreeSet::new(),
            config_index: vec![BTreeSet::new(); n],
            winners: vec![None; n],
        }
    }

    /// Next sequential id ("00001", "00002", ...).
    pub fn next_id(&self) -> String {
        format!("{:05}", self.members.len() + 1)
    }

    pub fn add(&mut self, ind: Individual) {
        debug_assert!(
            !self.index_by_id.contains_key(&ind.id),
            "duplicate id {}",
            ind.id
        );
        let idx = self.members.len() as u32;
        // resolve the lineage link before registering the new id, so a
        // (malformed) self-parent stays dangling instead of looping
        let parent = ind
            .parents
            .first()
            .and_then(|p| self.index_by_id.get(p).copied());
        self.parent_index.push(parent);
        self.index_by_id.insert(ind.id.clone(), idx);
        self.index_by_fp
            .entry(ind.genome.fingerprint_hash())
            .or_insert(idx);
        let score = ind.score();
        if let Some(ts) = ind.outcome.timings() {
            let nc = self.config_index.len();
            for (i, &t) in ts.iter().enumerate().take(nc) {
                if self.winners[i].map(|(_, best)| t < best).unwrap_or(true) {
                    self.winners[i] = Some((idx, t));
                }
                self.config_index[i].insert((total_order_key(t), idx));
            }
            let s = score.expect("successful member has a geomean");
            self.leaderboard.insert((total_order_key(s), idx));
            self.successful_order.push(idx);
        }
        self.scores.push(score);
        self.members.push(ind);
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    pub fn members(&self) -> &[Individual] {
        &self.members
    }

    /// Member by position (the indexes speak in positions).
    pub fn member(&self, idx: usize) -> &Individual {
        &self.members[idx]
    }

    /// Position of `id`, if present.
    pub fn index_of(&self, id: &str) -> Option<usize> {
        self.index_by_id.get(id).map(|&i| i as usize)
    }

    /// Position of member `idx`'s first parent (resolved at `add`;
    /// always strictly less than `idx`).
    pub fn parent_of(&self, idx: usize) -> Option<usize> {
        self.parent_index[idx].map(|i| i as usize)
    }

    /// Cached feedback geomean of member `idx` (`None` for failures).
    pub fn score_of(&self, idx: usize) -> Option<f64> {
        self.scores[idx]
    }

    pub fn by_id(&self, id: &str) -> Option<&Individual> {
        self.index_of(id).map(|i| &self.members[i])
    }

    /// All members with successful timings (insertion order).
    pub fn successful(&self) -> Vec<&Individual> {
        self.successful_order
            .iter()
            .map(|&i| &self.members[i as usize])
            .collect()
    }

    /// How many members succeeded — `successful().len()` without the
    /// allocation.
    pub fn successful_count(&self) -> usize {
        self.successful_order.len()
    }

    /// Successful member indices in insertion order.
    pub fn successful_indices(&self) -> &[u32] {
        &self.successful_order
    }

    /// The i-th successful member in insertion order.
    pub fn nth_successful(&self, i: usize) -> &Individual {
        &self.members[self.successful_order[i] as usize]
    }

    /// Successful members from best geomean down (ties keep insertion
    /// order, matching a stable sort of [`Population::successful`] by
    /// score) — the selector's top-k source; maintained incrementally,
    /// never re-sorted per call.
    pub fn leaderboard_members(&self) -> impl Iterator<Item = &Individual> + '_ {
        self.leaderboard
            .iter()
            .map(move |&(_, i)| &self.members[i as usize])
    }

    /// Best (lowest feedback geomean) successful member. O(log n): the
    /// leaderboard head, which is the first-minimum member exactly as
    /// the old `min_by` scan returned.
    pub fn best(&self) -> Option<&Individual> {
        self.leaderboard
            .iter()
            .next()
            .map(|&(_, i)| &self.members[i as usize])
    }

    /// Per-config winners: for each feedback config index, the id of
    /// the member with the lowest timing there (first strictly-lower
    /// wins). O(configs) per call from the incrementally maintained
    /// winner table — no archive scan, no per-improvement id clones.
    pub fn config_winners(&self) -> Vec<Option<String>> {
        self.winners
            .iter()
            .map(|w| w.map(|(i, _)| self.members[i as usize].id.clone()))
            .collect()
    }

    /// Successful members that beat `base` on at least one feedback
    /// config, as (first beating config index, member) in insertion
    /// order — the selector's per-config-specialist candidate set
    /// (paper App. A.1 sample 3). Answered from the per-config timing
    /// indexes in time proportional to the result instead of a full
    /// archive scan; the candidate list (content, order, first-config
    /// attribution) is exactly what the old scan produced.
    pub fn config_beaters(&self, base: &Individual) -> Vec<(usize, &Individual)> {
        let Some(base_ts) = base.outcome.timings() else {
            return Vec::new();
        };
        let base_idx = self.index_of(&base.id).map(|i| i as u32);
        let nc = base_ts.len().min(self.config_index.len());
        // walk configs high→low so the surviving map entry per member
        // is its lowest (first) beating config
        let mut firsts: HashMap<u32, usize> = HashMap::new();
        for i in (0..nc).rev() {
            let bt = base_ts[i];
            // everything total-ordered below bt; `<` (the scan's
            // comparison) re-confirms, so e.g. a negative-NaN timing —
            // below bt in total order but not under `<` — stays out
            for &(k, idx) in self.config_index[i].range(..(total_order_key(bt), 0)) {
                if total_order_decode(k) < bt && Some(idx) != base_idx {
                    firsts.insert(idx, i);
                }
            }
        }
        // fully sorted on the next line, so drain order cannot leak
        let mut out: Vec<(u32, usize)> = firsts.into_iter().collect(); // detlint: allow(DL003)
        out.sort_unstable_by_key(|&(idx, _)| idx);
        out.into_iter()
            .map(|(idx, cfg)| (cfg, &self.members[idx as usize]))
            .collect()
    }

    /// Ancestor chain of `id` (nearest first), following first
    /// parents. O(depth): a pure index walk. Parents resolve at `add`
    /// against earlier members only, so the chain strictly descends —
    /// cycles are unrepresentable (the old quadratic cycle guard is
    /// gone by construction).
    pub fn ancestors(&self, id: &str) -> Vec<&Individual> {
        let mut out: Vec<&Individual> = Vec::new();
        let mut cur = self.index_of(id);
        while let Some(i) = cur {
            match self.parent_of(i) {
                Some(p) => {
                    out.push(&self.members[p]);
                    cur = Some(p);
                }
                None => break,
            }
        }
        out
    }

    /// Nearest common ancestor of two members, if any.
    pub fn common_ancestor(&self, a: &str, b: &str) -> Option<&Individual> {
        let mut anc_b: std::collections::HashSet<usize> = std::collections::HashSet::new();
        let mut cur = self.index_of(b);
        while let Some(i) = cur {
            cur = self.parent_of(i);
            if let Some(p) = cur {
                anc_b.insert(p);
            }
        }
        let mut cur = self.index_of(a);
        while let Some(i) = cur {
            cur = self.parent_of(i);
            if let Some(p) = cur {
                if anc_b.contains(&p) {
                    return Some(&self.members[p]);
                }
            }
        }
        None
    }

    /// O(1) duplicate probe by precomputed content hash — the batch
    /// planner's form of [`Population::find_duplicate`] (it already
    /// holds the hash and the genome; the genome confirms the positive
    /// path against hash collisions).
    pub fn contains_genome(&self, fp: u64, g: &KernelGenome) -> bool {
        match self.index_by_fp.get(&fp) {
            Some(&idx) if self.members[idx as usize].genome == *g => true,
            // hash hit on a different genome (collision — astronomically
            // rare): answer exactly anyway
            Some(_) => self.members.iter().any(|m| m.genome == *g),
            None => false,
        }
    }

    /// Members whose genome matches (dedup check; string-fingerprint
    /// equality is genome equality). The common negative case is O(1)
    /// via the content-hash index; positive hits return the first
    /// matching member, confirmed by genome equality.
    pub fn find_duplicate(&self, g: &KernelGenome) -> Option<&Individual> {
        let &idx = self.index_by_fp.get(&g.fingerprint_hash())?;
        let m = &self.members[idx as usize];
        if m.genome == *g {
            return Some(m);
        }
        // collision fallback: exact scan, same answer the string-keyed
        // archive gave
        self.members.iter().find(|m| m.genome == *g)
    }

    /// Serialize to JSONL (one member per line, append-friendly).
    pub fn to_jsonl(&self) -> String {
        let mut s = String::new();
        for m in &self.members {
            m.write_json(&mut s);
            s.push('\n');
        }
        s
    }

    /// Load from JSONL produced by [`Population::to_jsonl`].
    pub fn from_jsonl(
        text: &str,
        feedback_configs: Vec<GemmConfig>,
    ) -> Result<Population, String> {
        let mut pop = Population::new(feedback_configs);
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let v = json::parse(line).map_err(|e| format!("line {}: {e}", i + 1))?;
            pop.add(Individual::from_json(&v)?);
        }
        Ok(pop)
    }

    /// Save to a file.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_jsonl())
    }

    /// Load from a file.
    pub fn load(
        path: &std::path::Path,
        feedback_configs: Vec<GemmConfig>,
    ) -> Result<Population, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Population::from_jsonl(&text, feedback_configs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::seeds;
    use crate::workload::FEEDBACK_CONFIGS;

    fn ind(id: &str, parents: &[&str], score_base: f64) -> Individual {
        Individual {
            id: id.into(),
            parents: parents.iter().map(|s| s.to_string()).collect(),
            genome: seeds::mfma_seed(),
            experiment: format!("exp-{id}"),
            report: String::new(),
            outcome: EvalOutcome::Timings(vec![score_base; 6]),
        }
    }

    fn pop() -> Population {
        let mut p = Population::new(FEEDBACK_CONFIGS.to_vec());
        p.add(ind("00001", &[], 1000.0));
        p.add(ind("00002", &["00001"], 800.0));
        p.add(ind("00003", &["00001"], 900.0));
        p.add(ind("00004", &["00002"], 600.0));
        p
    }

    #[test]
    fn ids_sequential() {
        let p = pop();
        assert_eq!(p.next_id(), "00005");
    }

    #[test]
    fn best_is_lowest_geomean() {
        let p = pop();
        assert_eq!(p.best().unwrap().id, "00004");
    }

    #[test]
    fn failed_members_excluded_from_best() {
        let mut p = pop();
        let mut bad = ind("00005", &["00004"], 1.0);
        bad.outcome = EvalOutcome::IncorrectResult("race".into());
        p.add(bad);
        assert_eq!(p.best().unwrap().id, "00004");
        assert_eq!(p.successful().len(), 4);
        assert_eq!(p.successful_count(), 4);
        assert!(p.score_of(4).is_none());
    }

    #[test]
    fn leaderboard_sorted_with_stable_ties() {
        let mut p = pop();
        // a tie with 00003's 900.0: insertion order breaks it
        p.add(ind("00005", &["00001"], 900.0));
        let order: Vec<&str> = p
            .leaderboard_members()
            .map(|m| m.id.as_str())
            .collect();
        assert_eq!(order, vec!["00004", "00002", "00003", "00005", "00001"]);
        // equivalent to a stable sort of successful() by score
        let mut sorted = p.successful();
        sorted.sort_by(|a, b| {
            a.score().unwrap().total_cmp(&b.score().unwrap())
        });
        let expect: Vec<&str> = sorted.iter().map(|m| m.id.as_str()).collect();
        assert_eq!(order, expect);
    }

    #[test]
    fn ancestors_follow_base_parent() {
        let p = pop();
        let chain: Vec<&str> = p.ancestors("00004").iter().map(|m| m.id.as_str()).collect();
        assert_eq!(chain, vec!["00002", "00001"]);
        assert_eq!(p.parent_of(p.index_of("00004").unwrap()), p.index_of("00002"));
    }

    #[test]
    fn common_ancestor_of_divergent_branches() {
        let p = pop();
        // 00004 descends from 00002; 00003 descends from 00001 directly
        let ca = p.common_ancestor("00004", "00003").unwrap();
        assert_eq!(ca.id, "00001");
    }

    #[test]
    fn config_winners_tracks_per_config() {
        let mut p = Population::new(FEEDBACK_CONFIGS.to_vec());
        let mut a = ind("00001", &[], 100.0);
        a.outcome = EvalOutcome::Timings(vec![100.0, 100.0, 100.0, 100.0, 100.0, 100.0]);
        let mut b = ind("00002", &[], 100.0);
        // b is better only on config 2
        b.outcome = EvalOutcome::Timings(vec![150.0, 150.0, 50.0, 150.0, 150.0, 150.0]);
        p.add(a);
        p.add(b);
        let winners = p.config_winners();
        assert_eq!(winners[0].as_deref(), Some("00001"));
        assert_eq!(winners[2].as_deref(), Some("00002"));
    }

    #[test]
    fn config_beaters_reports_first_beating_config_in_insertion_order() {
        let mut p = Population::new(FEEDBACK_CONFIGS.to_vec());
        let mut base = ind("00001", &[], 100.0);
        base.outcome = EvalOutcome::Timings(vec![100.0; 6]);
        let mut b = ind("00002", &[], 100.0);
        b.outcome = EvalOutcome::Timings(vec![150.0, 90.0, 80.0, 150.0, 150.0, 150.0]);
        let mut c = ind("00003", &[], 100.0);
        c.outcome = EvalOutcome::Timings(vec![150.0; 6]); // beats nowhere
        let mut d = ind("00004", &[], 100.0);
        d.outcome = EvalOutcome::Timings(vec![99.0, 150.0, 150.0, 150.0, 150.0, 150.0]);
        p.add(base);
        p.add(b);
        p.add(c);
        p.add(d);
        let base = p.by_id("00001").unwrap();
        let beaters: Vec<(usize, &str)> = p
            .config_beaters(base)
            .into_iter()
            .map(|(i, m)| (i, m.id.as_str()))
            .collect();
        // insertion order; 00002's first beating config is 1, not 2
        assert_eq!(beaters, vec![(1, "00002"), (0, "00004")]);
        // the base itself never appears, even though it ties itself
        assert!(beaters.iter().all(|(_, id)| *id != "00001"));
    }

    #[test]
    fn jsonl_roundtrip() {
        let p = pop();
        let text = p.to_jsonl();
        let back = Population::from_jsonl(&text, FEEDBACK_CONFIGS.to_vec()).unwrap();
        assert_eq!(back.len(), p.len());
        assert_eq!(back.best().unwrap().id, "00004");
        assert_eq!(back.by_id("00003").unwrap().experiment, "exp-00003");
    }

    #[test]
    fn jsonl_roundtrip_failures() {
        let mut p = Population::new(FEEDBACK_CONFIGS.to_vec());
        let mut bad = ind("00001", &[], 1.0);
        bad.outcome = EvalOutcome::CompileFailure("LDS overflow".into());
        p.add(bad);
        let back = Population::from_jsonl(&p.to_jsonl(), FEEDBACK_CONFIGS.to_vec()).unwrap();
        assert!(matches!(
            back.by_id("00001").unwrap().outcome,
            EvalOutcome::CompileFailure(_)
        ));
    }

    #[test]
    fn streamed_member_json_matches_tree_emitter() {
        let mut p = pop();
        let mut bad = ind("00005", &["00004"], 1.0);
        bad.outcome = EvalOutcome::IncorrectResult("race \"x\"\nline".into());
        p.add(bad);
        for m in p.members() {
            let mut streamed = String::new();
            m.write_json(&mut streamed);
            assert_eq!(streamed, m.to_json().to_string(), "{}", m.id);
        }
    }

    #[test]
    fn duplicate_detection() {
        let p = pop();
        assert!(p.find_duplicate(&seeds::mfma_seed()).is_some());
        assert_eq!(p.find_duplicate(&seeds::mfma_seed()).unwrap().id, "00001");
        assert!(p.find_duplicate(&seeds::human_oracle()).is_none());
        let g = seeds::mfma_seed();
        assert!(p.contains_genome(g.fingerprint_hash(), &g));
        let h = seeds::human_oracle();
        assert!(!p.contains_genome(h.fingerprint_hash(), &h));
    }
}
