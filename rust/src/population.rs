//! The kernel population: every submitted individual, its lineage, and
//! its benchmark results.
//!
//! The paper's Evolutionary Selector sees "the members of the
//! population ... identified by an ID, and the IDs of each of their
//! 'parents' ..., as well as the benchmark results for 6 specified
//! MxKxN input configurations" (§3.1). This module is exactly that
//! ledger, plus lineage queries (ancestors, divergence points,
//! per-config winners) and JSONL persistence so a run can resume.

use crate::genome::KernelGenome;
use crate::metrics::geomean;
use crate::util::json::{self, Json};
use crate::workload::GemmConfig;

/// Outcome of one submission, as the platform reported it.
#[derive(Debug, Clone, PartialEq)]
pub enum EvalOutcome {
    /// Correct kernel with per-config timings (microseconds), in the
    /// feedback suite's config order.
    Timings(Vec<f64>),
    /// Rejected before running (compile/launch failure) with reason.
    CompileFailure(String),
    /// Ran but produced wrong results.
    IncorrectResult(String),
}

impl EvalOutcome {
    pub fn timings(&self) -> Option<&[f64]> {
        match self {
            EvalOutcome::Timings(t) => Some(t),
            _ => None,
        }
    }

    pub fn is_success(&self) -> bool {
        matches!(self, EvalOutcome::Timings(_))
    }
}

/// One member of the population.
#[derive(Debug, Clone, PartialEq)]
pub struct Individual {
    /// Zero-padded sequential id ("00001"), as in App. A.1.
    pub id: String,
    /// Parent ids: `[base]` or `[base, reference]`; empty for seeds.
    pub parents: Vec<String>,
    pub genome: KernelGenome,
    /// The experiment description that led to this kernel (seeds carry
    /// their provenance instead).
    pub experiment: String,
    /// The Kernel Writer's self-report of techniques actually applied.
    pub report: String,
    pub outcome: EvalOutcome,
}

impl Individual {
    /// Geomean of the feedback timings (None for failed submissions).
    pub fn score(&self) -> Option<f64> {
        self.outcome.timings().map(geomean)
    }

    pub fn to_json(&self) -> Json {
        let outcome = match &self.outcome {
            EvalOutcome::Timings(t) => Json::obj(vec![
                ("kind", Json::Str("timings".into())),
                ("us", Json::Arr(t.iter().map(|&x| Json::Num(x)).collect())),
            ]),
            EvalOutcome::CompileFailure(msg) => Json::obj(vec![
                ("kind", Json::Str("compile_failure".into())),
                ("msg", Json::Str(msg.clone())),
            ]),
            EvalOutcome::IncorrectResult(msg) => Json::obj(vec![
                ("kind", Json::Str("incorrect_result".into())),
                ("msg", Json::Str(msg.clone())),
            ]),
        };
        Json::obj(vec![
            ("id", Json::Str(self.id.clone())),
            (
                "parents",
                Json::Arr(self.parents.iter().map(|p| Json::Str(p.clone())).collect()),
            ),
            ("genome", self.genome.to_json()),
            ("experiment", Json::Str(self.experiment.clone())),
            ("report", Json::Str(self.report.clone())),
            ("outcome", outcome),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Individual, String> {
        let id = v
            .get("id")
            .and_then(|x| x.as_str())
            .ok_or("missing id")?
            .to_string();
        let parents = v
            .get("parents")
            .and_then(|x| x.as_arr())
            .ok_or("missing parents")?
            .iter()
            .map(|p| p.as_str().map(String::from).ok_or("bad parent id"))
            .collect::<Result<Vec<_>, _>>()?;
        let genome = KernelGenome::from_json(v.get("genome").ok_or("missing genome")?)?;
        let experiment = v
            .get("experiment")
            .and_then(|x| x.as_str())
            .unwrap_or("")
            .to_string();
        let report = v
            .get("report")
            .and_then(|x| x.as_str())
            .unwrap_or("")
            .to_string();
        let o = v.get("outcome").ok_or("missing outcome")?;
        let outcome = match o.get("kind").and_then(|x| x.as_str()) {
            Some("timings") => EvalOutcome::Timings(
                o.get("us")
                    .and_then(|x| x.as_arr())
                    .ok_or("missing us")?
                    .iter()
                    .map(|x| x.as_f64().ok_or("bad timing"))
                    .collect::<Result<Vec<_>, _>>()?,
            ),
            Some("compile_failure") => EvalOutcome::CompileFailure(
                o.get("msg").and_then(|x| x.as_str()).unwrap_or("").into(),
            ),
            Some("incorrect_result") => EvalOutcome::IncorrectResult(
                o.get("msg").and_then(|x| x.as_str()).unwrap_or("").into(),
            ),
            _ => return Err("bad outcome kind".into()),
        };
        Ok(Individual {
            id,
            parents,
            genome,
            experiment,
            report,
            outcome,
        })
    }
}

/// The growing list of kernels (paper Fig. 1, right side).
#[derive(Debug, Clone, Default)]
pub struct Population {
    members: Vec<Individual>,
    /// The feedback configs the timing vectors are indexed by.
    pub feedback_configs: Vec<GemmConfig>,
    /// Fingerprint cache: set of genome fingerprints present, so the
    /// writer's duplicate check is O(1) instead of re-rendering every
    /// member's fingerprint per probe (perf pass, EXPERIMENTS.md §Perf).
    fingerprints: std::collections::HashSet<String>,
}

impl Population {
    pub fn new(feedback_configs: Vec<GemmConfig>) -> Self {
        Population {
            members: Vec::new(),
            feedback_configs,
            fingerprints: std::collections::HashSet::new(),
        }
    }

    /// Next sequential id ("00001", "00002", ...).
    pub fn next_id(&self) -> String {
        format!("{:05}", self.members.len() + 1)
    }

    pub fn add(&mut self, ind: Individual) {
        debug_assert!(self.by_id(&ind.id).is_none(), "duplicate id {}", ind.id);
        self.fingerprints.insert(ind.genome.fingerprint());
        self.members.push(ind);
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    pub fn members(&self) -> &[Individual] {
        &self.members
    }

    pub fn by_id(&self, id: &str) -> Option<&Individual> {
        self.members.iter().find(|m| m.id == id)
    }

    /// All members with successful timings.
    pub fn successful(&self) -> Vec<&Individual> {
        self.members.iter().filter(|m| m.outcome.is_success()).collect()
    }

    /// Best (lowest feedback geomean) successful member.
    pub fn best(&self) -> Option<&Individual> {
        self.successful()
            .into_iter()
            .min_by(|a, b| a.score().partial_cmp(&b.score()).unwrap())
    }

    /// Per-config winners: for each feedback config index, the id of
    /// the member with the lowest timing there.
    pub fn config_winners(&self) -> Vec<Option<String>> {
        let n = self.feedback_configs.len();
        let mut winners: Vec<Option<(String, f64)>> = vec![None; n];
        for m in self.successful() {
            if let Some(ts) = m.outcome.timings() {
                for (i, &t) in ts.iter().enumerate().take(n) {
                    if winners[i].as_ref().map(|(_, best)| t < *best).unwrap_or(true) {
                        winners[i] = Some((m.id.clone(), t));
                    }
                }
            }
        }
        winners.into_iter().map(|w| w.map(|(id, _)| id)).collect()
    }

    /// Ancestor chain of `id` (nearest first), following first parents.
    pub fn ancestors(&self, id: &str) -> Vec<&Individual> {
        let mut out: Vec<&Individual> = Vec::new();
        let mut cur = self.by_id(id);
        while let Some(ind) = cur {
            if let Some(parent_id) = ind.parents.first() {
                cur = self.by_id(parent_id);
                if let Some(p) = cur {
                    if out.iter().any(|x| x.id == p.id) {
                        break; // cycle guard
                    }
                    out.push(p);
                }
            } else {
                break;
            }
        }
        out
    }

    /// Nearest common ancestor of two members, if any.
    pub fn common_ancestor(&self, a: &str, b: &str) -> Option<&Individual> {
        let anc_a: Vec<&Individual> = self.ancestors(a);
        let anc_b: std::collections::HashSet<&str> =
            self.ancestors(b).iter().map(|m| m.id.as_str()).collect();
        anc_a.into_iter().find(|m| anc_b.contains(m.id.as_str()))
    }

    /// O(1) duplicate probe by precomputed fingerprint — the batch
    /// planner's form of [`Population::find_duplicate`] (it already
    /// holds the fingerprint and only needs a yes/no).
    pub fn contains_fingerprint(&self, fingerprint: &str) -> bool {
        self.fingerprints.contains(fingerprint)
    }

    /// Members whose genome fingerprint matches (dedup check). The
    /// common (negative) case is O(1) via the fingerprint cache.
    pub fn find_duplicate(&self, g: &KernelGenome) -> Option<&Individual> {
        let fp = g.fingerprint();
        if !self.fingerprints.contains(&fp) {
            return None;
        }
        self.members.iter().find(|m| m.genome.fingerprint() == fp)
    }

    /// Serialize to JSONL (one member per line, append-friendly).
    pub fn to_jsonl(&self) -> String {
        let mut s = String::new();
        for m in &self.members {
            s.push_str(&m.to_json().to_string());
            s.push('\n');
        }
        s
    }

    /// Load from JSONL produced by [`Population::to_jsonl`].
    pub fn from_jsonl(
        text: &str,
        feedback_configs: Vec<GemmConfig>,
    ) -> Result<Population, String> {
        let mut pop = Population::new(feedback_configs);
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let v = json::parse(line).map_err(|e| format!("line {}: {e}", i + 1))?;
            pop.add(Individual::from_json(&v)?);
        }
        Ok(pop)
    }

    /// Save to a file.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_jsonl())
    }

    /// Load from a file.
    pub fn load(
        path: &std::path::Path,
        feedback_configs: Vec<GemmConfig>,
    ) -> Result<Population, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Population::from_jsonl(&text, feedback_configs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::seeds;
    use crate::workload::FEEDBACK_CONFIGS;

    fn ind(id: &str, parents: &[&str], score_base: f64) -> Individual {
        Individual {
            id: id.into(),
            parents: parents.iter().map(|s| s.to_string()).collect(),
            genome: seeds::mfma_seed(),
            experiment: format!("exp-{id}"),
            report: String::new(),
            outcome: EvalOutcome::Timings(vec![score_base; 6]),
        }
    }

    fn pop() -> Population {
        let mut p = Population::new(FEEDBACK_CONFIGS.to_vec());
        p.add(ind("00001", &[], 1000.0));
        p.add(ind("00002", &["00001"], 800.0));
        p.add(ind("00003", &["00001"], 900.0));
        p.add(ind("00004", &["00002"], 600.0));
        p
    }

    #[test]
    fn ids_sequential() {
        let p = pop();
        assert_eq!(p.next_id(), "00005");
    }

    #[test]
    fn best_is_lowest_geomean() {
        let p = pop();
        assert_eq!(p.best().unwrap().id, "00004");
    }

    #[test]
    fn failed_members_excluded_from_best() {
        let mut p = pop();
        let mut bad = ind("00005", &["00004"], 1.0);
        bad.outcome = EvalOutcome::IncorrectResult("race".into());
        p.add(bad);
        assert_eq!(p.best().unwrap().id, "00004");
        assert_eq!(p.successful().len(), 4);
    }

    #[test]
    fn ancestors_follow_base_parent() {
        let p = pop();
        let chain: Vec<&str> = p.ancestors("00004").iter().map(|m| m.id.as_str()).collect();
        assert_eq!(chain, vec!["00002", "00001"]);
    }

    #[test]
    fn common_ancestor_of_divergent_branches() {
        let p = pop();
        // 00004 descends from 00002; 00003 descends from 00001 directly
        let ca = p.common_ancestor("00004", "00003").unwrap();
        assert_eq!(ca.id, "00001");
    }

    #[test]
    fn config_winners_tracks_per_config() {
        let mut p = Population::new(FEEDBACK_CONFIGS.to_vec());
        let mut a = ind("00001", &[], 100.0);
        a.outcome = EvalOutcome::Timings(vec![100.0, 100.0, 100.0, 100.0, 100.0, 100.0]);
        let mut b = ind("00002", &[], 100.0);
        // b is better only on config 2
        b.outcome = EvalOutcome::Timings(vec![150.0, 150.0, 50.0, 150.0, 150.0, 150.0]);
        p.add(a);
        p.add(b);
        let winners = p.config_winners();
        assert_eq!(winners[0].as_deref(), Some("00001"));
        assert_eq!(winners[2].as_deref(), Some("00002"));
    }

    #[test]
    fn jsonl_roundtrip() {
        let p = pop();
        let text = p.to_jsonl();
        let back = Population::from_jsonl(&text, FEEDBACK_CONFIGS.to_vec()).unwrap();
        assert_eq!(back.len(), p.len());
        assert_eq!(back.best().unwrap().id, "00004");
        assert_eq!(back.by_id("00003").unwrap().experiment, "exp-00003");
    }

    #[test]
    fn jsonl_roundtrip_failures() {
        let mut p = Population::new(FEEDBACK_CONFIGS.to_vec());
        let mut bad = ind("00001", &[], 1.0);
        bad.outcome = EvalOutcome::CompileFailure("LDS overflow".into());
        p.add(bad);
        let back = Population::from_jsonl(&p.to_jsonl(), FEEDBACK_CONFIGS.to_vec()).unwrap();
        assert!(matches!(
            back.by_id("00001").unwrap().outcome,
            EvalOutcome::CompileFailure(_)
        ));
    }

    #[test]
    fn duplicate_detection() {
        let p = pop();
        assert!(p.find_duplicate(&seeds::mfma_seed()).is_some());
        assert!(p.find_duplicate(&seeds::human_oracle()).is_none());
        assert!(p.contains_fingerprint(&seeds::mfma_seed().fingerprint()));
        assert!(!p.contains_fingerprint(&seeds::human_oracle().fingerprint()));
    }
}
