//! Statistics used across the platform: geometric mean (the
//! competition's leaderboard metric), summary stats, and convergence
//! tracking for the Figure-1 loop.


/// Geometric mean — the leaderboard aggregation (§4.5). Panics on an
/// empty slice. Timings must be positive and finite: a NaN/inf/zero
/// entry is a platform bug, surfaced by the debug assertion instead of
/// silently skewing the leaderboard (release builds clamp to a tiny
/// epsilon as a last resort).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "geomean of empty slice");
    debug_assert!(
        xs.iter().all(|x| x.is_finite() && *x > 0.0),
        "geomean over non-positive/non-finite timings: {xs:?}"
    );
    let log_sum: f64 = xs.iter().map(|&x| x.max(1e-12).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation (p in [0, 100]). Total order
/// over f64 (NaN sorts last) — a NaN timing must not panic the
/// reporting path mid-run.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// One point on a convergence curve: best leaderboard geomean after
/// each evaluated submission.
#[derive(Debug, Clone, PartialEq)]
pub struct ConvergencePoint {
    pub submission: usize,
    pub best_geomean_us: f64,
}

/// Running best-so-far tracker producing the Figure-1 convergence
/// series the benches emit.
#[derive(Debug, Clone, Default)]
pub struct ConvergenceCurve {
    pub points: Vec<ConvergencePoint>,
}

impl ConvergenceCurve {
    pub fn record(&mut self, submission: usize, geomean_us: f64) {
        let best = self
            .points
            .last()
            .map(|p| p.best_geomean_us.min(geomean_us))
            .unwrap_or(geomean_us);
        self.points.push(ConvergencePoint {
            submission,
            best_geomean_us: best,
        });
    }

    pub fn best(&self) -> Option<f64> {
        self.points.last().map(|p| p.best_geomean_us)
    }

    /// First submission index reaching `target_us`, if any.
    pub fn first_reaching(&self, target_us: f64) -> Option<usize> {
        self.points
            .iter()
            .find(|p| p.best_geomean_us <= target_us)
            .map(|p| p.submission)
    }

    /// CSV rendering (`submission,best_geomean_us`).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("submission,best_geomean_us\n");
        for p in &self.points {
            s.push_str(&format!("{},{:.3}\n", p.submission, p.best_geomean_us));
        }
        s
    }

    /// Compact ASCII sparkline of best-so-far (log scale).
    pub fn ascii_sparkline(&self, width: usize) -> String {
        if self.points.is_empty() {
            return String::new();
        }
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let vals: Vec<f64> = self.points.iter().map(|p| p.best_geomean_us.ln()).collect();
        let (lo, hi) = vals
            .iter()
            .fold((f64::MAX, f64::MIN), |(l, h), &v| (l.min(v), h.max(v)));
        let span = (hi - lo).max(1e-9);
        let step = (vals.len() as f64 / width as f64).max(1.0);
        let mut out = String::new();
        let mut i = 0.0;
        while (i as usize) < vals.len() && out.chars().count() < width {
            let v = vals[i as usize];
            let idx = (((v - lo) / span) * 7.0).round() as usize;
            out.push(BARS[idx.min(7)]);
            i += step;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
        assert!((geomean(&[5.0]) - 5.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_dominated_by_log_scale() {
        // geometric mean is robust to one huge outlier vs arithmetic
        let g = geomean(&[10.0, 10.0, 10.0, 10_000.0]);
        let m = mean(&[10.0, 10.0, 10.0, 10_000.0]);
        assert!(g < m / 5.0);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn geomean_empty_panics() {
        geomean(&[]);
    }

    #[test]
    fn stddev_and_percentile() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!((mean(&xs) - 3.0).abs() < 1e-12);
        assert!((stddev(&xs) - 1.5811388).abs() < 1e-6);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 3.0).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_total_order_survives_nan() {
        // NaN sorts last under total_cmp instead of panicking the sort
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!((percentile(&xs, 100.0 / 3.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "non-positive/non-finite")]
    #[cfg(debug_assertions)]
    fn geomean_surfaces_non_finite_timings() {
        geomean(&[10.0, f64::NAN]);
    }

    #[test]
    fn convergence_monotone_nonincreasing() {
        let mut c = ConvergenceCurve::default();
        for (i, t) in [500.0, 400.0, 450.0, 300.0, 350.0].iter().enumerate() {
            c.record(i, *t);
        }
        let bests: Vec<f64> = c.points.iter().map(|p| p.best_geomean_us).collect();
        assert_eq!(bests, vec![500.0, 400.0, 400.0, 300.0, 300.0]);
        assert_eq!(c.best(), Some(300.0));
        assert_eq!(c.first_reaching(400.0), Some(1));
        assert_eq!(c.first_reaching(100.0), None);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut c = ConvergenceCurve::default();
        c.record(0, 123.456);
        let csv = c.to_csv();
        assert!(csv.starts_with("submission,best_geomean_us\n"));
        assert!(csv.contains("0,123.456"));
    }

    #[test]
    fn sparkline_renders() {
        let mut c = ConvergenceCurve::default();
        for i in 0..100 {
            c.record(i, 5000.0 / (1.0 + i as f64));
        }
        let s = c.ascii_sparkline(40);
        assert!(!s.is_empty());
        assert!(s.chars().count() <= 40);
    }
}
