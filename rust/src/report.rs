//! Report rendering: Table-1 style comparisons, convergence curves,
//! run transcripts (the App.-A.1/A.2-style YAML blocks), and roofline
//! accounting for EXPERIMENTS.md.

pub mod lineage;

use crate::metrics::ConvergenceCurve;
use crate::scientist::IterationLog;

/// One row of a Table-1-style comparison.
#[derive(Debug, Clone)]
pub struct TableRow {
    pub label: String,
    pub paper_us: Option<f64>,
    pub measured_us: f64,
    pub comment: String,
}

/// Render a markdown table of comparison rows.
pub fn render_table(title: &str, rows: &[TableRow]) -> String {
    let mut s = format!("### {title}\n\n");
    s.push_str("| Implementation | Paper (us) | Measured (us) | Comment |\n");
    s.push_str("|---|---|---|---|\n");
    for r in rows {
        let paper = r
            .paper_us
            .map(|p| format!("{p:.0}"))
            .unwrap_or_else(|| "-".into());
        s.push_str(&format!(
            "| {} | {} | {:.1} | {} |\n",
            r.label, paper, r.measured_us, r.comment
        ));
    }
    s
}

/// Render a convergence curve as CSV + sparkline + summary lines.
pub fn render_convergence(name: &str, curve: &ConvergenceCurve) -> String {
    let mut s = format!("### Convergence: {name}\n\n");
    if let Some(best) = curve.best() {
        s.push_str(&format!(
            "best geomean: {best:.1} us after {} scored submissions\n",
            curve.points.len()
        ));
    }
    s.push_str(&format!("trend: {}\n\n", curve.ascii_sparkline(60)));
    s.push_str("```csv\n");
    s.push_str(&curve.to_csv());
    s.push_str("```\n");
    s
}

/// Render one iteration's transcript in the paper's appendix style.
pub fn render_iteration(log: &IterationLog) -> String {
    let mut s = format!("--- iteration {} ---\n", log.iteration);
    s.push_str(&format!(
        "basis_code: \"{}\"\nbasis_reference: \"{}\"\nrationale: >\n  {}\n",
        log.selection.base_id,
        log.selection.reference_id,
        log.selection.rationale.replace('\n', "\n  ")
    ));
    s.push_str("avenues:\n");
    for a in &log.avenue_names {
        s.push_str(&format!("  - {a}\n"));
    }
    s.push_str("chosen_experiments:\n");
    for e in &log.chosen_experiments {
        s.push_str(&format!("  - {e}\n"));
    }
    s.push_str(&format!("submitted: {:?}\n", log.submitted_ids));
    s
}

/// Speedup helper for report prose.
pub fn speedup(baseline_us: f64, measured_us: f64) -> f64 {
    baseline_us / measured_us
}

/// One-line scheduler summary: mode, lane occupancy, pipeline depth,
/// planning activity (DESIGN.md §8).
pub fn render_pipeline(stats: &crate::scientist::PipelineStats) -> String {
    let mode = if stats.pipelined {
        "steady-state pipeline"
    } else {
        "lockstep"
    };
    let mut s = format!(
        "scheduler: {mode} over {} lane(s) | occupancy {:.0}% | in-flight mean {:.1} \
         (max {}) | {} planning rounds, {} duplicates replanned",
        stats.lanes,
        stats.lane_occupancy * 100.0,
        stats.mean_in_flight,
        stats.max_in_flight,
        stats.planning_rounds,
        stats.replanned_duplicates
    );
    // only rendered when the screen tier saw work: a `[screen]`-off
    // run's summary stays byte-identical to a build without the tier
    if stats.screened > 0 {
        s.push_str(&format!(
            " | screen: {} scored, {} promoted, {} rejected",
            stats.screened, stats.screen_promoted, stats.screen_rejected
        ));
    }
    // same rule for the lint gate (DESIGN.md §13): gate-off summaries
    // stay byte-identical to a build without the analysis layer
    if stats.linted > 0 {
        s.push_str(&format!(
            " | lint: {} checked, {} rejected pre-submission",
            stats.linted, stats.lint_rejected
        ));
    }
    // and for the recovery layer (DESIGN.md §14): a faults-off run —
    // or a chaos run that happened to need no recovery — renders no
    // fragment
    if stats.fault_retries > 0 || stats.fault_abandoned > 0 {
        s.push_str(&format!(
            " | faults: {} retried, {} abandoned",
            stats.fault_retries, stats.fault_abandoned
        ));
    }
    s
}

/// Render a genome's diagnostic list (the `lint` CLI subcommand,
/// DESIGN.md §13): one [`crate::analysis::Diagnostic::render`] line per
/// finding under a label, or an explicit clean verdict — an empty list
/// must read as "checked and passed", never as "not checked".
pub fn render_lint(label: &str, diags: &[crate::analysis::Diagnostic]) -> String {
    use crate::analysis::Severity;
    if diags.is_empty() {
        return format!("{label}: clean (no diagnostics)\n");
    }
    let errors = diags.iter().filter(|d| d.severity == Severity::Error).count();
    let mut s = format!(
        "{label}: {} diagnostic(s), {} error(s)\n",
        diags.len(),
        errors
    );
    for d in diags {
        s.push_str("  ");
        s.push_str(&d.render());
        s.push('\n');
    }
    s
}

/// One-line bottleneck-mix summary over a run's profiled submissions
/// (DESIGN.md §11). Empty when the run carried no mix (`[profile]`
/// off) or the mix saw no profiled work — so guided-off report output
/// stays byte-identical to a build without the profile layer.
pub fn render_profiles(mix: Option<&crate::sim::ProfileMix>) -> String {
    match mix {
        Some(m) if m.total() > 0 => format!(
            "bottlenecks: {} ({} profiled submissions)\n",
            m.render(),
            m.total()
        ),
        _ => String::new(),
    }
}

/// One-line federated-archive summary (DESIGN.md §12). Empty when the
/// run carried no federation stats (`[federation]` off) or the archive
/// contributed nothing — so off-run report output stays byte-identical
/// to a build without the federation layer.
pub fn render_federation(stats: Option<&crate::store::FederationStats>) -> String {
    match stats {
        Some(s) if s.hits > 0 || s.warm_start_injected > 0 => format!(
            "federation: {} cross-run cache hit(s), {} warm-start elite(s) injected\n",
            s.hits, s.warm_start_injected
        ),
        _ => String::new(),
    }
}

/// One-line fault-injection + recovery summary (DESIGN.md §14). Empty
/// when the run carried no fault state (`[faults]` off) — so off-run
/// report output stays byte-identical to a build without the layer. A
/// chaos run always renders, even when zero faults fired: "checked and
/// clean" must never read as "not checked".
pub fn render_faults(summary: Option<&crate::eval::FaultSummary>) -> String {
    match summary {
        Some(f) => format!(
            "faults: {} injected ({} transient, {} lane death(s), {} straggler timeout(s), \
             {} suspect timing(s)) | recovery: {} retried, {} abandoned, {} lane(s) retired\n",
            f.stats.injected(),
            f.stats.transients,
            f.stats.lane_deaths,
            f.stats.straggler_timeouts,
            f.stats.suspects,
            f.retries,
            f.abandoned,
            f.retired_lanes
        ),
        None => String::new(),
    }
}

/// Render a campaign's per-workload summary as a markdown table. The
/// bottleneck-mix column appears only when at least one run carried a
/// profile mix (`[profile] guided`): an all-off campaign's table stays
/// byte-identical to pre-profile output.
pub fn render_campaign(outcome: &crate::scientist::campaign::CampaignOutcome) -> String {
    let with_mix = outcome
        .results
        .iter()
        .any(|r| r.outcome.profile_mix.is_some());
    // the lint column follows the profile-mix rule: it appears only
    // when at least one run's gate saw work, so a gate-off campaign's
    // table stays byte-identical to pre-lint output
    let with_lint = outcome.results.iter().any(|r| r.outcome.pipeline.linted > 0);
    let mut s = String::from("### Campaign summary\n\n");
    s.push_str(
        "| Workload | Best | Feedback geomean (us) | Leaderboard (us) | Submissions | Cache h/m | Platform time (min) | Lane occupancy | Screened/promoted |",
    );
    if with_lint {
        s.push_str(" Linted/rejected |");
    }
    if with_mix {
        s.push_str(" Bottlenecks |");
    }
    s.push('\n');
    s.push_str("|---|---|---|---|---|---|---|---|---|");
    if with_lint {
        s.push_str("---|");
    }
    if with_mix {
        s.push_str("---|");
    }
    s.push('\n');
    for r in &outcome.results {
        let lb = r
            .outcome
            .leaderboard_us
            .map(|x| format!("{x:.1}"))
            .unwrap_or_else(|| "-".into());
        s.push_str(&format!(
            "| {} | {} | {:.1} | {} | {} | {}/{} | {:.0} | {:.0}% | {}/{} |",
            r.workload,
            r.outcome.best_id,
            r.outcome.best_geomean_us,
            lb,
            r.outcome.submissions,
            r.cache_stats.0,
            r.cache_stats.1,
            r.outcome.wall_clock_s / 60.0,
            r.outcome.pipeline.lane_occupancy * 100.0,
            r.outcome.pipeline.screened,
            r.outcome.pipeline.screen_promoted
        ));
        if with_lint {
            s.push_str(&format!(
                " {}/{} |",
                r.outcome.pipeline.linted, r.outcome.pipeline.lint_rejected
            ));
        }
        if with_mix {
            let mix = r
                .outcome
                .profile_mix
                .as_ref()
                .map(|m| m.render())
                .unwrap_or_else(|| "-".into());
            s.push_str(&format!(" {mix} |"));
        }
        s.push('\n');
    }
    s.push_str(&format!(
        "\ntotal submissions: {}; campaign wall clock (concurrent): {:.0} min\n",
        outcome.total_submissions(),
        outcome.wall_clock_s() / 60.0
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::{Selection, SelectionPolicy, Selector};
    use crate::metrics::ConvergenceCurve;

    #[test]
    fn table_renders_markdown() {
        let rows = vec![
            TableRow {
                label: "PyTorch reference".into(),
                paper_us: Some(850.0),
                measured_us: 840.0,
                comment: "library fp16".into(),
            },
            TableRow {
                label: "This work".into(),
                paper_us: None,
                measured_us: 300.0,
                comment: "LLM-only".into(),
            },
        ];
        let s = render_table("Table 1", &rows);
        assert!(s.contains("| PyTorch reference | 850 | 840.0 | library fp16 |"));
        assert!(s.contains("| This work | - | 300.0 | LLM-only |"));
    }

    #[test]
    fn convergence_renders() {
        let mut c = ConvergenceCurve::default();
        c.record(1, 500.0);
        c.record(2, 400.0);
        let s = render_convergence("test", &c);
        assert!(s.contains("best geomean: 400.0 us"));
        assert!(s.contains("submission,best_geomean_us"));
    }

    #[test]
    fn iteration_transcript_has_paper_fields() {
        let _ = Selector::new(SelectionPolicy::PaperLlm); // shape check only
        let log = IterationLog {
            iteration: 3,
            selection: Selection {
                base_id: "00052".into(),
                reference_id: "00046".into(),
                policy: None,
                rationale: "Run 00052 is selected as the basis code...".into(),
            },
            avenue_names: vec!["LDS Bank Conflict Mitigation".into()],
            chosen_experiments: vec!["pad LDS rows".into()],
            submitted_ids: vec!["00053".into()],
        };
        let s = render_iteration(&log);
        assert!(s.contains("basis_code: \"00052\""));
        assert!(s.contains("basis_reference: \"00046\""));
        assert!(s.contains("rationale: >"));
    }

    #[test]
    fn speedup_math() {
        assert_eq!(speedup(850.0, 425.0), 2.0);
    }

    #[test]
    fn campaign_table_renders_every_workload_row() {
        use crate::scientist::campaign::{CampaignOutcome, WorkloadRunResult};
        use crate::scientist::{PipelineStats, RunOutcome};
        let row = |w: &str, best: f64| WorkloadRunResult {
            workload: w.into(),
            cache_stats: (2, 10),
            outcome: RunOutcome {
                workload: w.into(),
                best_geomean_us: best,
                best_id: "00009".into(),
                submissions: 12,
                wall_clock_s: 1080.0,
                curve: ConvergenceCurve::default(),
                leaderboard_us: Some(best * 1.1),
                pipeline: PipelineStats {
                    pipelined: true,
                    lanes: 4,
                    lane_occupancy: 0.9,
                    ..Default::default()
                },
                profile_mix: None,
                federation: None,
                faults: None,
            },
        };
        let out = CampaignOutcome {
            results: vec![row("fp8-gemm", 400.0), row("row-softmax", 120.0)],
        };
        let s = render_campaign(&out);
        assert!(s.contains("| fp8-gemm | 00009 | 400.0 |"), "{s}");
        assert!(s.contains("| row-softmax | 00009 | 120.0 |"), "{s}");
        assert!(s.contains("total submissions: 24"), "{s}");
        assert!(s.contains("2/10"), "{s}");
        assert!(s.contains("| 90% |"), "{s}");
        // no run carried a profile mix: the column must not exist
        assert!(!s.contains("Bottlenecks"), "{s}");
    }

    #[test]
    fn campaign_table_adds_bottleneck_column_only_when_profiled() {
        use crate::scientist::campaign::{CampaignOutcome, WorkloadRunResult};
        use crate::scientist::{PipelineStats, RunOutcome};
        use crate::sim::{Bottleneck, ProfileMix};
        let mut mix = ProfileMix::default();
        mix.add(Bottleneck::Memory);
        mix.add(Bottleneck::Memory);
        mix.add(Bottleneck::Compute);
        let out = CampaignOutcome {
            results: vec![WorkloadRunResult {
                workload: "fp8-gemm".into(),
                cache_stats: (0, 5),
                outcome: RunOutcome {
                    workload: "fp8-gemm".into(),
                    best_geomean_us: 400.0,
                    best_id: "00009".into(),
                    submissions: 12,
                    wall_clock_s: 1080.0,
                    curve: ConvergenceCurve::default(),
                    leaderboard_us: None,
                    pipeline: PipelineStats::default(),
                    profile_mix: Some(mix),
                    federation: None,
                    faults: None,
                },
            }],
        };
        let s = render_campaign(&out);
        assert!(s.contains("Bottlenecks |"), "{s}");
        assert!(s.contains("| memory 2, compute 1 |"), "{s}");
    }

    #[test]
    fn profile_summary_renders_only_for_populated_mixes() {
        use crate::sim::{Bottleneck, ProfileMix};
        assert_eq!(render_profiles(None), "");
        let empty = ProfileMix::default();
        assert_eq!(
            render_profiles(Some(&empty)),
            "",
            "a zero-count mix renders nothing"
        );
        let mut mix = ProfileMix::default();
        mix.add(Bottleneck::Lds);
        mix.add(Bottleneck::Memory);
        mix.add(Bottleneck::Memory);
        let s = render_profiles(Some(&mix));
        assert_eq!(s, "bottlenecks: memory 2, lds 1 (3 profiled submissions)\n");
    }

    #[test]
    fn federation_summary_renders_only_when_the_archive_contributed() {
        use crate::store::FederationStats;
        assert_eq!(render_federation(None), "");
        assert_eq!(
            render_federation(Some(&FederationStats::default())),
            "",
            "an attached-but-idle archive renders nothing"
        );
        let s = render_federation(Some(&FederationStats {
            hits: 7,
            warm_start_injected: 2,
        }));
        assert_eq!(
            s,
            "federation: 7 cross-run cache hit(s), 2 warm-start elite(s) injected\n"
        );
    }

    #[test]
    fn pipeline_summary_renders_both_modes() {
        use crate::scientist::PipelineStats;
        let stats = PipelineStats {
            pipelined: true,
            lanes: 4,
            lane_occupancy: 0.9375,
            mean_in_flight: 3.8,
            max_in_flight: 4,
            planning_rounds: 11,
            replanned_duplicates: 2,
            screened: 0,
            screen_promoted: 0,
            screen_rejected: 0,
            linted: 0,
            lint_rejected: 0,
            fault_retries: 0,
            fault_abandoned: 0,
        };
        let s = render_pipeline(&stats);
        assert!(s.contains("steady-state pipeline over 4 lane(s)"), "{s}");
        assert!(s.contains("occupancy 94%"), "{s}");
        assert!(s.contains("2 duplicates replanned"), "{s}");
        // screening off: no screen fragment at all (report diffs of
        // off runs against pre-screen baselines stay clean)
        assert!(!s.contains("screen:"), "{s}");
        // lint gate off: same rule
        assert!(!s.contains("lint:"), "{s}");
        // faults off: same rule
        assert!(!s.contains("faults:"), "{s}");
        let lockstep = PipelineStats {
            pipelined: false,
            ..stats.clone()
        };
        assert!(render_pipeline(&lockstep).contains("lockstep"));
        let screened = PipelineStats {
            screened: 12,
            screen_promoted: 7,
            screen_rejected: 5,
            ..stats.clone()
        };
        let s = render_pipeline(&screened);
        assert!(s.contains("screen: 12 scored, 7 promoted, 5 rejected"), "{s}");
        let linted = PipelineStats {
            linted: 9,
            lint_rejected: 3,
            ..stats.clone()
        };
        let s = render_pipeline(&linted);
        assert!(s.contains("lint: 9 checked, 3 rejected pre-submission"), "{s}");
        let faulted = PipelineStats {
            fault_retries: 4,
            fault_abandoned: 1,
            ..stats
        };
        let s = render_pipeline(&faulted);
        assert!(s.contains("faults: 4 retried, 1 abandoned"), "{s}");
    }

    #[test]
    fn fault_summary_renders_only_when_the_layer_ran() {
        use crate::eval::{FaultStats, FaultSummary};
        assert_eq!(render_faults(None), "");
        // a chaos run renders even when no fault fired: "checked and
        // clean" must never read as "not checked"
        let quiet = FaultSummary {
            stats: FaultStats::default(),
            retries: 0,
            abandoned: 0,
            retired_lanes: 0,
        };
        let s = render_faults(Some(&quiet));
        assert!(s.starts_with("faults: 0 injected"), "{s}");
        let busy = FaultSummary {
            stats: FaultStats {
                transients: 5,
                lane_deaths: 1,
                straggler_timeouts: 2,
                suspects: 3,
                ..Default::default()
            },
            retries: 9,
            abandoned: 2,
            retired_lanes: 1,
        };
        let s = render_faults(Some(&busy));
        assert!(s.contains("11 injected"), "{s}");
        assert!(s.contains("5 transient"), "{s}");
        assert!(s.contains("recovery: 9 retried, 2 abandoned, 1 lane(s) retired"), "{s}");
    }

    #[test]
    fn lint_report_renders_diagnostics_or_a_clean_verdict() {
        use crate::analysis::lint;
        use crate::genome::{seeds, KernelGenome};
        use crate::gpu::MI300;
        use crate::workload;
        let w = workload::default_workload();
        // an invalid genome renders its error line under the label
        let g = KernelGenome {
            block_m: 48,
            ..seeds::naive_hip()
        };
        let diags = lint(&g, &MI300, w.as_ref());
        let s = render_lint("candidate", &diags);
        assert!(s.starts_with("candidate: "), "{s}");
        assert!(s.contains("error(s)\n"), "{s}");
        assert!(s.contains("  error "), "{s}");
        // an empty list is an explicit clean verdict
        assert_eq!(render_lint("seed", &[]), "seed: clean (no diagnostics)\n");
    }

    #[test]
    fn campaign_table_adds_lint_column_only_when_the_gate_saw_work() {
        use crate::scientist::campaign::{CampaignOutcome, WorkloadRunResult};
        use crate::scientist::{PipelineStats, RunOutcome};
        let row = |linted: u64, lint_rejected: u64| WorkloadRunResult {
            workload: "fp8-gemm".into(),
            cache_stats: (0, 5),
            outcome: RunOutcome {
                workload: "fp8-gemm".into(),
                best_geomean_us: 400.0,
                best_id: "00009".into(),
                submissions: 12,
                wall_clock_s: 1080.0,
                curve: ConvergenceCurve::default(),
                leaderboard_us: None,
                pipeline: PipelineStats {
                    linted,
                    lint_rejected,
                    ..Default::default()
                },
                profile_mix: None,
                federation: None,
                faults: None,
            },
        };
        let off = render_campaign(&CampaignOutcome {
            results: vec![row(0, 0)],
        });
        assert!(!off.contains("Linted"), "{off}");
        let on = render_campaign(&CampaignOutcome {
            results: vec![row(9, 3)],
        });
        assert!(on.contains("Linted/rejected |"), "{on}");
        assert!(on.contains("| 9/3 |"), "{on}");
    }

    #[test]
    fn pipeline_summary_survives_zero_occupancy() {
        // a zero-makespan run (all-cache-hit or zero budget) reports
        // 0.0 occupancy from the platform — the summary must print 0%,
        // never NaN%
        use crate::scientist::PipelineStats;
        let stats = PipelineStats {
            lanes: 1,
            lane_occupancy: 0.0,
            ..Default::default()
        };
        let s = render_pipeline(&stats);
        assert!(s.contains("occupancy 0%"), "{s}");
        assert!(!s.contains("NaN"), "{s}");
    }
}
