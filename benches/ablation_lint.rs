//! Ablation: **the static lint gate on vs off** (DESIGN.md §13).
//!
//! The gate runs `analysis::lint` over every planned child and turns
//! would-be platform compile failures into zero-cost ledger records —
//! the doomed genome never occupies an evaluation lane and never
//! consumes quota. This bench quantifies what that buys at an **equal
//! submission quota** (60 submissions, 4 lanes): the lane-seconds each
//! leg burns on genomes that were statically doomed.
//!
//! Both legs share the surrogate-infidelity knobs the e2e robustness
//! test uses, so the writer's repair loop leaks invalid children at a
//! realistic rate. Every platform submission costs the backend's
//! constant `submission_cost_s()` of lane time, so the wasted total is
//! `cost × |compile failures in the submission log|`. Asserted:
//!
//!   * the gated leg wastes **zero** lane-seconds — the analyzer's
//!     Error set provably covers the platform's reject set, so nothing
//!     doomed may reach a lane;
//!   * the ungated legs waste a nonzero total across seeds — the gate
//!     has real work at this infidelity, and the margin (geomean of
//!     the per-seed cost-shifted ratios) clears 1.0.
//!
//! Results land in `BENCH_lint.json` for the CI artifact.
//!
//! Run: `cargo bench --bench ablation_lint`

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::eval::EvalBackend;
use gpu_kernel_scientist::metrics::geomean;
use gpu_kernel_scientist::population::EvalOutcome;
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::util::bench::header;
use gpu_kernel_scientist::util::json::Json;

const SEEDS: u64 = 6;
const BUDGET: u64 = 60;
const LANES: u32 = 4;

struct Leg {
    /// Lane-seconds burned on platform compile failures.
    wasted_s: f64,
    /// Platform submissions that were compile failures.
    doomed_subs: u64,
    /// Children the gate rejected pre-submission (gated leg only).
    lint_rejected: u64,
    best_us: f64,
}

fn run_leg(seed: u64, gated: bool) -> Leg {
    let mut cfg = RunConfig::default()
        .with_seed(seed)
        .with_budget(BUDGET)
        .with_parallelism(LANES)
        .with_pipeline(true)
        .with_lint_gate(gated);
    // same infidelity both legs: the *planner* output is what differs
    cfg.llm.rubric_infidelity = 0.3;
    cfg.llm.temperature = 2.0;
    let mut run = ScientistRun::new(cfg).expect("setup");
    let outcome = run.run_to_completion().expect("run");
    let cost = run.platform.backend_mut().submission_cost_s();
    let doomed = run
        .platform
        .log()
        .iter()
        .filter(|r| matches!(r.outcome, EvalOutcome::CompileFailure(_)))
        .count() as u64;
    Leg {
        wasted_s: doomed as f64 * cost,
        doomed_subs: doomed,
        lint_rejected: outcome.pipeline.lint_rejected,
        best_us: outcome.best_geomean_us,
    }
}

fn main() {
    header("ablation — static lint gate (lane-seconds on doomed genomes)");

    let cost = SimBackend::new(1).submission_cost_s();
    let mut ratios = Vec::new();
    let mut ungated_total_s = 0.0;
    let mut gated_total_s = 0.0;
    let mut rejected_total = 0u64;

    println!(
        "{:>6} {:>22} {:>26} {:>10}",
        "seed", "ungated (doomed, s)", "gated (rejected, s)", "ratio"
    );
    for seed in 0..SEEDS {
        let ungated = run_leg(seed, false);
        let gated = run_leg(seed, true);
        assert_eq!(
            gated.doomed_subs, 0,
            "seed {seed}: the gate let {} doomed genome(s) onto a lane",
            gated.doomed_subs
        );
        ungated_total_s += ungated.wasted_s;
        gated_total_s += gated.wasted_s;
        rejected_total += gated.lint_rejected;
        // cost-shifted ratio: +1 submission of lane time on both sides
        // keeps zero-failure seeds at exactly 1.0 instead of 0/0
        let ratio = (ungated.wasted_s + cost) / (gated.wasted_s + cost);
        ratios.push(ratio);
        println!(
            "{seed:>6} {:>12} {:>8.0}s {:>14} {:>10.0}s {ratio:>9.2}x   \
             (bests {:.1} / {:.1} us)",
            ungated.doomed_subs,
            ungated.wasted_s,
            gated.lint_rejected,
            gated.wasted_s,
            ungated.best_us,
            gated.best_us,
        );
    }

    let margin = geomean(&ratios);
    println!(
        "\nlane-seconds on doomed genomes at equal quota ({BUDGET} submissions, \
         {LANES} lanes): ungated {ungated_total_s:.0}s vs gated {gated_total_s:.0}s \
         — margin {margin:.2}x (target > 1.0)"
    );
    assert!(
        ungated_total_s > 0.0,
        "no ungated run wasted a lane on a doomed genome — the gate has \
         nothing to show at this infidelity; raise the knobs"
    );
    assert!(
        rejected_total > 0,
        "the gate never rejected a child across {SEEDS} seeds"
    );
    assert!(
        margin > 1.0,
        "the gate must strictly reduce lane-seconds wasted on doomed \
         genomes (got {margin:.2}x)"
    );

    let doc = Json::obj(vec![
        ("seeds", Json::Num(SEEDS as f64)),
        ("budget", Json::Num(BUDGET as f64)),
        ("lanes", Json::Num(LANES as f64)),
        ("submission_cost_s", Json::Num(cost)),
        ("ungated_wasted_lane_s", Json::Num(ungated_total_s)),
        ("gated_wasted_lane_s", Json::Num(gated_total_s)),
        ("gate_rejections", Json::Num(rejected_total as f64)),
        ("margin_geomean", Json::Num(margin)),
    ]);
    std::fs::write("BENCH_lint.json", doc.to_string()).expect("write BENCH_lint.json");
    println!("lint ablation written to BENCH_lint.json");
    println!("ablation_lint shape: OK");
}
