//! Bench: **scientist vs classic autotuners** at equal submission
//! budget (paper §2 positions OpenTuner/Kernel-Tuner as narrower,
//! complementary approaches over the same space).
//!
//! Run: `cargo bench --bench baselines`

use gpu_kernel_scientist::baselines::{Annealer, GeneticAlgorithm, HillClimber, RandomSearch, Tuner};
use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::eval::{EvalPlatform, PlatformConfig};
use gpu_kernel_scientist::metrics::geomean;
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::util::bench::header;

fn main() {
    header("baselines — scientist vs tuners at equal budget");
    const SEEDS: u64 = 5;
    const BUDGET: u64 = 120;
    println!("{:24} {:>16} {:>12}", "strategy", "mean best (us)", "worst (us)");

    let mut scientist = Vec::new();
    for seed in 0..SEEDS {
        let cfg = RunConfig::default().with_seed(seed).with_budget(BUDGET);
        let mut run = ScientistRun::new(cfg).expect("setup");
        scientist.push(run.run_to_completion().expect("run").best_geomean_us);
    }
    let worst = scientist.iter().cloned().fold(f64::MIN, f64::max);
    println!(
        "{:24} {:>16.1} {:>12.1}",
        "scientist (paper)",
        geomean(&scientist),
        worst
    );

    let mut table: Vec<(&str, f64)> = vec![("scientist", geomean(&scientist))];
    for which in ["random", "hillclimb", "anneal", "genetic"] {
        let mut bests = Vec::new();
        for seed in 0..SEEDS {
            let mut platform = EvalPlatform::new(
                SimBackend::new(seed),
                PlatformConfig {
                    submission_quota: Some(BUDGET),
                    ..Default::default()
                },
            );
            let out = match which {
                "random" => RandomSearch { seed }.run(&mut platform, BUDGET),
                "hillclimb" => HillClimber {
                    seed,
                    ..Default::default()
                }
                .run(&mut platform, BUDGET),
                "anneal" => Annealer {
                    seed,
                    ..Default::default()
                }
                .run(&mut platform, BUDGET),
                _ => GeneticAlgorithm {
                    seed,
                    ..Default::default()
                }
                .run(&mut platform, BUDGET),
            };
            bests.push(out.best_geomean_us);
        }
        let worst = bests.iter().cloned().fold(f64::MIN, f64::max);
        println!("{:24} {:>16.1} {:>12.1}", which, geomean(&bests), worst);
        table.push((which, geomean(&bests)));
    }
    for (name, score) in &table[1..] {
        println!(
            "scientist vs {:10}: {:+.1}%",
            name,
            (score / table[0].1 - 1.0) * 100.0
        );
    }
}
