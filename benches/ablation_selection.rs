//! Ablation: the **Evolutionary Selector policy** (paper §3.1).
//!
//! The paper replaces mechanical selection with LLM judgement over the
//! multi-objective situation. This bench compares that policy against
//! random selection and greedy best-only selection at equal budget.
//!
//! Run: `cargo bench --bench ablation_selection`

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::metrics::geomean;
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::util::bench::header;

fn main() {
    header("ablation — selection policy");
    const SEEDS: u64 = 5;
    const BUDGET: u64 = 100;
    println!("{:28} {:>16} {:>12}", "policy", "mean best (us)", "worst (us)");
    let mut results = Vec::new();
    for (name, policy) in [
        ("paper (LLM judgement)", SelectionPolicy::PaperLlm),
        ("greedy best-only", SelectionPolicy::GreedyBest),
        ("random", SelectionPolicy::Random),
    ] {
        let mut bests = Vec::new();
        for seed in 0..SEEDS {
            let mut cfg = RunConfig::default().with_seed(seed).with_budget(BUDGET);
            cfg.selection_policy = policy;
            let mut run = ScientistRun::new(cfg).expect("setup");
            bests.push(run.run_to_completion().expect("run").best_geomean_us);
        }
        let worst = bests.iter().cloned().fold(f64::MIN, f64::max);
        println!("{:28} {:>16.1} {:>12.1}", name, geomean(&bests), worst);
        results.push((name, geomean(&bests)));
    }
    let paper = results[0].1;
    for (name, score) in &results[1..] {
        println!(
            "paper vs {name}: {:+.1}% {}",
            (score / paper - 1.0) * 100.0,
            if *score >= paper { "(paper better or equal)" } else { "(ablation better)" }
        );
    }
}
