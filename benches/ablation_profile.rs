//! Ablation: **profile-guided avenue priors on vs off** (DESIGN.md §11).
//!
//! Every submission is profiled into a bottleneck class either way —
//! the `[profile] guided` knob only controls whether the designer
//! conditions its avenue priors on the base genome's classified
//! bottleneck. This bench quantifies what that feedback loop buys at an
//! **equal submission quota**: how many submissions each leg needs to
//! reach the same best score.
//!
//! Per seed, both legs run to the full budget and the target is the
//! *worse* of the two final bests, so both curves provably reach it
//! (when guidance wins on quality — the usual case — the target is
//! exactly the timing-only run's best, the ISSUE's criterion). Seed
//! evaluations are identical across legs, so the scored quantity is
//! *planned* submissions to target (first-reaching index minus the
//! seed count). Asserted: guided needs ≥ 15% fewer, geomean over
//! seeds. Also locks the knob surface: the timing-only outcome carries
//! no bottleneck mix, the guided one a populated mix.
//!
//! Run: `cargo bench --bench ablation_profile`

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::metrics::geomean;
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::util::bench::header;
use gpu_kernel_scientist::workload::{self, Workload};

const SEEDS: u64 = 6;
const BUDGET: u64 = 60;
const LANES: u32 = 4;

struct Leg {
    best_us: f64,
    curve: gpu_kernel_scientist::metrics::ConvergenceCurve,
    mix: Option<gpu_kernel_scientist::sim::ProfileMix>,
}

fn run_leg(seed: u64, guided: bool) -> Leg {
    let cfg = RunConfig::default()
        .with_seed(seed)
        .with_budget(BUDGET)
        .with_parallelism(LANES)
        .with_pipeline(true)
        .with_profile_guided(guided);
    let mut run = ScientistRun::new(cfg).expect("setup");
    let outcome = run.run_to_completion().expect("run");
    Leg {
        best_us: outcome.best_geomean_us,
        curve: outcome.curve,
        mix: outcome.profile_mix,
    }
}

fn main() {
    header("ablation — profile-guided avenue priors (bottleneck feedback)");

    let n_seeds = workload::registry()
        .into_iter()
        .find(|w| w.name() == RunConfig::default().workload)
        .expect("default workload is registered")
        .starting_population()
        .len();

    let mut timing_subs = Vec::new();
    let mut guided_subs = Vec::new();

    println!(
        "{:>6} {:>14} {:>26} {:>26}",
        "seed", "target", "timing-only (best, subs)", "guided (best, subs)"
    );
    for seed in 0..SEEDS {
        let timing = run_leg(seed, false);
        let guided = run_leg(seed, true);
        assert!(
            timing.mix.is_none(),
            "timing-only outcome must not surface a bottleneck mix"
        );
        let mix = guided.mix.as_ref().expect("guided outcome carries a mix");
        assert!(mix.total() > 0, "guided mix counted nothing");

        // the worse of the two finals — reached by both curves
        let target = timing.best_us.max(guided.best_us);
        let planned = |leg: &Leg| {
            let first = leg
                .curve
                .first_reaching(target)
                .expect("both legs reach the worse final");
            first.saturating_sub(n_seeds).max(1)
        };
        let (t, g) = (planned(&timing), planned(&guided));
        timing_subs.push(t as f64);
        guided_subs.push(g as f64);
        println!(
            "{seed:>6} {target:>11.1} us {:>14.1} us {:>7} {:>14.1} us {:>7}",
            timing.best_us, t, guided.best_us, g
        );
    }

    let ratio = geomean(&guided_subs) / geomean(&timing_subs);
    println!(
        "\nplanned submissions to target (guided / timing-only): {ratio:.3} \
         at equal quota ({BUDGET} submissions, {LANES} lanes; target <= 0.85)"
    );
    assert!(
        ratio <= 0.85,
        "profile guidance must cut submissions-to-target by >= 15% \
         (got {ratio:.3}x of the timing-only run)"
    );
    println!("ablation_profile shape: OK");
}
