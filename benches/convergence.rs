//! Bench: the **Figure-1 loop's convergence series** — best leaderboard
//! geomean vs submission count, the observable the paper's iterative
//! process produces (§4.4 "Iterative Refinement as a Discovery
//! Process"). Emits CSV + an ASCII curve for EXPERIMENTS.md.
//!
//! Run: `cargo bench --bench convergence`

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::report::render_convergence;
use gpu_kernel_scientist::util::bench::header;

fn main() {
    header("convergence — best-so-far vs sequential submissions");
    for seed in 0..3u64 {
        let cfg = RunConfig::default().with_seed(seed).with_budget(150);
        let mut run = ScientistRun::new(cfg).expect("setup");
        let outcome = run.run_to_completion().expect("run");
        println!(
            "{}",
            render_convergence(&format!("seed {seed}"), &outcome.curve)
        );
        // milestone table: submissions needed to cross key thresholds
        println!("  milestones (seed {seed}):");
        for target in [850.0, 600.0, 450.0, 300.0, 200.0] {
            match outcome.curve.first_reaching(target) {
                Some(n) => println!("    <= {target:6.0} us after {n:4} submissions"),
                None => println!("    <= {target:6.0} us: not reached"),
            }
        }
        println!();
    }
}
