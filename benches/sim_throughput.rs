//! Perf bench: L3 hot-path throughput (DESIGN.md §Perf).
//!
//! Targets:
//!   * simulator ≥ 100k genome-config estimates/s;
//!   * agent stages well under 1 ms per loop iteration;
//!   * the scientist loop's non-backend overhead negligible vs the
//!     90 s/submission platform latency the paper lived with.
//!
//! Run: `cargo bench --bench sim_throughput`

use std::time::Duration;

use gpu_kernel_scientist::agents::{AgentSuite, Designer, Selector};
use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::genome::seeds;
use gpu_kernel_scientist::gpu::MI300;
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::sim::estimate;
use gpu_kernel_scientist::util::bench::{bench, header, report};
use gpu_kernel_scientist::workload::FEEDBACK_CONFIGS;

fn main() {
    header("sim_throughput — L3 hot paths");
    let budget = Duration::from_millis(400);

    // 1) simulator estimate throughput
    let genomes: Vec<_> = seeds::all_seeds().into_iter().map(|(_, g)| g).collect();
    let mut i = 0usize;
    let r = bench("sim::estimate (1 genome-config)", budget, || {
        let g = &genomes[i % genomes.len()];
        let cfg = &FEEDBACK_CONFIGS[i % FEEDBACK_CONFIGS.len()];
        std::hint::black_box(estimate(&MI300, g, cfg).unwrap());
        i += 1;
    });
    report(&r);
    let per_s = r.throughput_per_s();
    println!("  => {:.0}k estimates/s (target >= 100k)", per_s / 1e3);
    assert!(per_s >= 100_000.0, "simulator below target: {per_s:.0}/s");

    // 2) full platform submission (6 configs x 3 reps + gates)
    let mut platform = gpu_kernel_scientist::eval::EvalPlatform::new(
        SimBackend::new(1),
        gpu_kernel_scientist::eval::PlatformConfig::default(),
    );
    let g = seeds::human_oracle();
    let r = bench("platform.submit (full submission)", budget, || {
        std::hint::black_box(platform.submit(&g));
    });
    report(&r);

    // 3) agent stages on a realistic mid-run population
    let mut run = ScientistRun::new(RunConfig::default().with_seed(9).with_budget(60))
        .expect("setup");
    run.run_to_completion().expect("run");
    let pop = run.population.clone();
    let mut suite = AgentSuite::paper(3);
    let selector = Selector::new(SelectionPolicy::PaperLlm);
    let r = bench("selector.select (60-member population)", budget, || {
        std::hint::black_box(selector.select(&pop, &mut suite.llm));
    });
    report(&r);
    let designer = Designer::default();
    let base = pop.best().unwrap().clone();
    let r = bench("designer.design (10 avenues -> 5 plans)", budget, || {
        std::hint::black_box(designer.design(
            &base.id,
            &base.genome,
            &pop,
            &suite.knowledge,
            &mut suite.llm,
            None,
        ));
    });
    report(&r);

    // 4) whole loop iteration overhead excluding backend: measured as
    //    iteration time minus the 3 submissions' backend share —
    //    approximated by timing an iteration (sim backend is ~us-fast,
    //    so this IS the loop overhead).
    let mut run2 = ScientistRun::new(
        RunConfig::default().with_seed(11).with_budget(1_000_000),
    )
    .expect("setup");
    let r = bench("scientist.run_iteration (3 submissions)", budget, || {
        std::hint::black_box(run2.run_iteration());
    });
    report(&r);
    assert!(
        r.mean_ns < 5_000_000.0,
        "loop iteration overhead must stay under 5 ms (got {})",
        r.mean_ns
    );
    println!("\nsim_throughput targets: OK");
}
