//! Ablation: **sequential vs parallel submission** (paper §5.1).
//!
//! "The system's current reliance on external evaluation means that it
//! does not operate in parallel, causing it to make slow optimization
//! progress overall." Two parts:
//!
//! **Part 1 — real lanes.** Since the executor refactor (DESIGN.md §3)
//! parallel lanes are actual worker threads, one forked backend each.
//! The same submission batch is pushed through 1 lane and through 3
//! lanes and the *measured* wall time is compared — parallelism=3 must
//! complete the identical budget in less real time (asserted whenever
//! the host has >1 CPU), while parallelism=1 must reproduce the
//! sequential submission path bit-for-bit.
//!
//! **Part 2 — fixed wall-clock curves.** Each submission occupies a
//! platform lane for ~90 simulated seconds; with L lanes, L
//! submissions complete per 90 s. The scientist loop runs to its
//! budget and the best-so-far curve is read at fixed wall-clock cuts
//! for 1 vs 3 lanes — quantifying how much of the paper's wall-time
//! the good-citizen rule cost.
//!
//! **Part 3 — lockstep vs steady-state pipeline.** Even with parallel
//! lanes, the lockstep scheduler submits at most 3 children per
//! iteration and then waits at the barrier; the pipeline scheduler
//! (DESIGN.md §8) refills each lane the moment it frees. The same
//! budget runs under both schedulers at parallelism {1, 2, 4, 8} and
//! the simulated wall clock + lane occupancy are compared: lockstep
//! saturates at the batch width while the pipeline keeps scaling.
//!
//! Run: `cargo bench --bench ablation_parallel`

use std::time::Instant;

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::eval::{EvalPlatform, PlatformConfig};
use gpu_kernel_scientist::genome::{edit, KernelGenome};
use gpu_kernel_scientist::metrics::{geomean, ConvergenceCurve};
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::util::bench::header;

const SUB_COST_S: f64 = 90.0;

/// Distinct valid genomes for the batch (single-edit neighbours of the
/// canonical seeds, deduplicated).
fn batch_genomes(n: usize) -> Vec<KernelGenome> {
    let mut out = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for base in [
        seeds::mfma_seed(),
        seeds::human_oracle(),
        seeds::pytorch_reference(),
        seeds::naive_hip(),
    ] {
        for (_, g) in edit::valid_neighbors(&base) {
            if seen.insert(g.fingerprint()) {
                out.push(g);
            }
            if out.len() == n {
                return out;
            }
        }
    }
    // keep the batch a multiple of 3 so the 3-lane accounting math in
    // main() stays exact even if the neighbourhood came up short
    out.truncate((out.len() / 3) * 3);
    assert!(out.len() >= 12, "not enough distinct genomes");
    out
}

/// Push one batch through a platform with `lanes` lanes; returns
/// (real seconds, simulated seconds, outcomes).
fn timed_batch(
    lanes: u32,
    reps_per_config: u32,
    jobs: &[KernelGenome],
) -> (f64, f64, Vec<gpu_kernel_scientist::population::EvalOutcome>) {
    let mut platform = EvalPlatform::new(
        SimBackend::new(17),
        PlatformConfig {
            reps_per_config,
            parallelism: lanes,
            ..Default::default()
        },
    );
    let t0 = Instant::now();
    let results = platform.submit_batch(jobs);
    let real_s = t0.elapsed().as_secs_f64();
    assert_eq!(results.len(), jobs.len(), "full budget must complete");
    (
        real_s,
        platform.wall_clock_s(),
        results.into_iter().map(|r| r.outcome).collect(),
    )
}

/// Best-so-far after `n_subs` submissions (from the curve).
fn best_after(curve: &ConvergenceCurve, n_subs: u64) -> Option<f64> {
    curve
        .points
        .iter()
        .take_while(|p| p.submission as u64 <= n_subs)
        .last()
        .map(|p| p.best_geomean_us)
}

fn main() {
    header("ablation — submission parallelism (real lanes + fixed wall-clock)");

    // ---- Part 1: real worker threads at the same submission budget ----
    let jobs = batch_genomes(48);
    // heavy per-submission timing sweep so lane threads dominate the
    // thread setup overhead
    let reps = 200;
    let (real_1, sim_1, _) = timed_batch(1, reps, &jobs);
    let (real_3, sim_3, _) = timed_batch(3, reps, &jobs);
    println!(
        "{} submissions x {reps} reps/config:",
        jobs.len()
    );
    println!(
        "  1 lane : {real_1:8.3} s real   {sim_1:8.0} s simulated platform time"
    );
    println!(
        "  3 lanes: {real_3:8.3} s real   {sim_3:8.0} s simulated platform time  ({:.2}x real speedup)",
        real_1 / real_3
    );
    assert!(
        (sim_3 - sim_1 / 3.0).abs() < 1e-6,
        "simulated accounting: 3 lanes = 1/3 the platform time"
    );
    // available_parallelism is cgroup-quota-aware on Linux, so a
    // `--cpus=1` container correctly reports 1 and skips the assert;
    // if it still fires on your host, suspect cpuset/shares throttling
    // that hides usable CPU time from the process.
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores >= 2 {
        assert!(
            real_3 < real_1,
            "3 real lanes must beat 1 lane in wall time ({real_3:.3}s vs {real_1:.3}s) — \
             {cores} CPUs reported; if this host throttles CPU time below that \
             (cpuset/shares), rerun with more headroom"
        );
    } else {
        println!("  (single-CPU host: skipping the real-speedup assertion)");
    }

    // parallelism=1 must reproduce the plain sequential path exactly
    let mut seq = EvalPlatform::new(SimBackend::new(17), PlatformConfig::default());
    let seq_out: Vec<_> = jobs.iter().map(|g| seq.submit(g)).collect();
    let (_, _, one_out) = timed_batch(1, 3, &jobs);
    let mut seq3 = EvalPlatform::new(SimBackend::new(17), PlatformConfig::default());
    let seq3_out: Vec<_> = jobs.iter().map(|g| seq3.submit(g)).collect();
    assert_eq!(seq_out, seq3_out, "sequential path is deterministic");
    assert_eq!(
        seq_out, one_out,
        "parallelism=1 batch == sequential submissions, bit for bit"
    );

    // ---- Part 2: best-so-far at fixed wall-clock cuts (paper §5.1) ----
    const SEEDS: u64 = 4;
    const BUDGET: u64 = 150;
    let mut curves = Vec::new();
    for seed in 0..SEEDS {
        let cfg = RunConfig::default().with_seed(seed).with_budget(BUDGET);
        let mut run = ScientistRun::new(cfg).expect("setup");
        let outcome = run.run_to_completion().expect("run");
        curves.push(outcome.curve);
    }

    println!(
        "\n{:>12} {:>20} {:>20} {:>10}",
        "wall-clock", "1 lane (paper)", "3 lanes", "speedup"
    );
    for wall_min in [15u64, 30, 60, 120, 180, 240] {
        let subs_1 = (wall_min as f64 * 60.0 / SUB_COST_S) as u64;
        let subs_3 = subs_1 * 3;
        let b1: Vec<f64> = curves
            .iter()
            .filter_map(|c| best_after(c, subs_1))
            .collect();
        let b3: Vec<f64> = curves
            .iter()
            .filter_map(|c| best_after(c, subs_3))
            .collect();
        if b1.is_empty() || b3.is_empty() {
            continue;
        }
        let g1 = geomean(&b1);
        let g3 = geomean(&b3);
        println!(
            "{:>9} min {:>17.1} us {:>17.1} us {:>9.2}x",
            wall_min,
            g1,
            g3,
            g1 / g3
        );
    }
    // the effect the paper predicts: early in the run, parallel lanes
    // are strictly ahead at equal wall-clock
    let early_1 = geomean(
        &curves
            .iter()
            .filter_map(|c| best_after(c, 10))
            .collect::<Vec<_>>(),
    );
    let early_3 = geomean(
        &curves
            .iter()
            .filter_map(|c| best_after(c, 30))
            .collect::<Vec<_>>(),
    );
    println!(
        "\nat 15 simulated minutes: 3 lanes are {:.2}x ahead of the good-citizen mode \
         (the paper's §5.1 'slow optimization progress')",
        early_1 / early_3
    );
    assert!(early_3 <= early_1 * 1.001);

    // ---- Part 3: lockstep vs steady-state pipeline (DESIGN.md §8) ----
    println!(
        "\n{:>6} {:>26} {:>26} {:>14}",
        "lanes", "lockstep (min, occ)", "pipeline (min, occ)", "rate speedup"
    );
    for lanes in [1u32, 2, 4, 8] {
        let run_scheduler = |pipeline: bool| {
            let cfg = RunConfig::default()
                .with_seed(3)
                .with_budget(60)
                .with_parallelism(lanes)
                .with_pipeline(pipeline);
            let mut run = ScientistRun::new(cfg).expect("setup");
            let outcome = run.run_to_completion().expect("run");
            (
                outcome.wall_clock_s,
                outcome.pipeline.lane_occupancy,
                outcome.submissions,
            )
        };
        let (lock_s, lock_occ, lock_subs) = run_scheduler(false);
        let (pipe_s, pipe_occ, pipe_subs) = run_scheduler(true);
        // normalize to simulated seconds per submission: trajectories
        // (and so total submissions) legitimately differ once the
        // pipeline plans against fresher results
        let lock_rate = lock_s / lock_subs as f64;
        let pipe_rate = pipe_s / pipe_subs as f64;
        println!(
            "{lanes:>6} {:>15.0} min {:>5.0}% {:>15.0} min {:>5.0}% {:>13.2}x",
            lock_s / 60.0,
            lock_occ * 100.0,
            pipe_s / 60.0,
            pipe_occ * 100.0,
            lock_rate / pipe_rate
        );
        assert!(
            pipe_rate <= lock_rate + 1e-9,
            "pipeline is never slower per submission ({lanes} lanes)"
        );
        if lanes >= 2 {
            assert!(
                pipe_occ >= lock_occ - 1e-9,
                "pipeline occupancy at least matches lockstep ({lanes} lanes)"
            );
        }
        if lanes >= 4 {
            // lockstep cannot fill more lanes than its 3-child batches
            assert!(
                pipe_occ > lock_occ,
                "pipeline strictly beats lockstep occupancy ({lanes} lanes)"
            );
        }
    }
    println!("ablation_parallel shape: OK");
}
