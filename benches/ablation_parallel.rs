//! Ablation: **sequential vs parallel submission** (paper §5.1).
//!
//! "The system's current reliance on external evaluation means that it
//! does not operate in parallel, causing it to make slow optimization
//! progress overall." Each submission occupies a platform lane for
//! ~90 s; with L lanes, L submissions complete per 90 s of wall clock.
//! This bench runs the loop to its submission budget, then reads the
//! best-so-far curve at fixed wall-clock cuts for 1 vs 3 lanes —
//! quantifying how much of the paper's wall-time the good-citizen rule
//! cost.
//!
//! Run: `cargo bench --bench ablation_parallel`

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::metrics::{geomean, ConvergenceCurve};
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::util::bench::header;

const SUB_COST_S: f64 = 90.0;

/// Best-so-far after `n_subs` submissions (from the curve).
fn best_after(curve: &ConvergenceCurve, n_subs: u64) -> Option<f64> {
    curve
        .points
        .iter()
        .take_while(|p| p.submission as u64 <= n_subs)
        .last()
        .map(|p| p.best_geomean_us)
}

fn main() {
    header("ablation — submission parallelism at fixed wall-clock");
    const SEEDS: u64 = 4;
    const BUDGET: u64 = 150;

    // one full run per seed; lanes only change the wall-clock mapping
    let mut curves = Vec::new();
    for seed in 0..SEEDS {
        let cfg = RunConfig::default().with_seed(seed).with_budget(BUDGET);
        let mut run = ScientistRun::new(cfg).expect("setup");
        let outcome = run.run_to_completion().expect("run");
        curves.push(outcome.curve);
    }

    println!(
        "{:>12} {:>20} {:>20} {:>10}",
        "wall-clock", "1 lane (paper)", "3 lanes", "speedup"
    );
    for wall_min in [15u64, 30, 60, 120, 180, 240] {
        let subs_1 = (wall_min as f64 * 60.0 / SUB_COST_S) as u64;
        let subs_3 = subs_1 * 3;
        let b1: Vec<f64> = curves
            .iter()
            .filter_map(|c| best_after(c, subs_1))
            .collect();
        let b3: Vec<f64> = curves
            .iter()
            .filter_map(|c| best_after(c, subs_3))
            .collect();
        if b1.is_empty() || b3.is_empty() {
            continue;
        }
        let g1 = geomean(&b1);
        let g3 = geomean(&b3);
        println!(
            "{:>9} min {:>17.1} us {:>17.1} us {:>9.2}x",
            wall_min,
            g1,
            g3,
            g1 / g3
        );
    }
    // the effect the paper predicts: early in the run, parallel lanes
    // are strictly ahead at equal wall-clock
    let early_1 = geomean(
        &curves
            .iter()
            .filter_map(|c| best_after(c, 10))
            .collect::<Vec<_>>(),
    );
    let early_3 = geomean(
        &curves
            .iter()
            .filter_map(|c| best_after(c, 30))
            .collect::<Vec<_>>(),
    );
    println!(
        "\nat 15 simulated minutes: 3 lanes are {:.2}x ahead of the good-citizen mode \
         (the paper's §5.1 'slow optimization progress')",
        early_1 / early_3
    );
    assert!(early_3 <= early_1 * 1.001);
    println!("ablation_parallel shape: OK");
}
