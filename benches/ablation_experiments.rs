//! Ablation: the **3-of-5 experiment selection rule** (paper §3.2).
//!
//! The paper picks (i) the most innovative, (ii) the highest-max, and
//! (iii) the highest-min predicted experiment "to keep a broad range
//! of alternative paths under consideration". Compared against pure
//! exploitation (top-3 by max) and pure exploration (random 3).
//!
//! Run: `cargo bench --bench ablation_experiments`

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::metrics::geomean;
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::util::bench::header;

fn main() {
    header("ablation — 3-of-5 experiment rule");
    const SEEDS: u64 = 5;
    const BUDGET: u64 = 100;
    println!("{:32} {:>16} {:>12}", "rule", "mean best (us)", "worst (us)");
    let mut results = Vec::new();
    for (name, rule) in [
        ("paper (innovative+max+min)", ExperimentRule::Paper),
        ("top-3 by max (exploit)", ExperimentRule::TopMax),
        ("random 3 (explore)", ExperimentRule::Random3),
    ] {
        let mut bests = Vec::new();
        for seed in 0..SEEDS {
            let mut cfg = RunConfig::default().with_seed(seed).with_budget(BUDGET);
            cfg.experiment_rule = rule;
            let mut run = ScientistRun::new(cfg).expect("setup");
            bests.push(run.run_to_completion().expect("run").best_geomean_us);
        }
        let worst = bests.iter().cloned().fold(f64::MIN, f64::max);
        println!("{:32} {:>16.1} {:>12.1}", name, geomean(&bests), worst);
        results.push((name, geomean(&bests)));
    }
    let paper = results[0].1;
    for (name, score) in &results[1..] {
        println!("paper vs {name}: {:+.1}%", (score / paper - 1.0) * 100.0);
    }
}
