//! Ablation: the **knowledge base** (paper §3, §4.1, §4.3).
//!
//! The paper's designer draws on a findings document from the
//! bootstrap hardware-probing phase plus digested external documents
//! (rocWMMA docs, CUDA blogs). Profiles:
//!   full    — everything (the paper's setup)
//!   generic — generic GPU lore only (no MI300-specific digests: no
//!             MFMA adoption, no scale re-purposing, no rocWMMA swizzle)
//!   minimal — tile tuning only (the pure hyper-parameter-tuner view)
//!
//! Run: `cargo bench --bench ablation_knowledge`

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::metrics::geomean;
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::util::bench::header;

fn main() {
    header("ablation — knowledge base profile");
    const SEEDS: u64 = 5;
    const BUDGET: u64 = 100;
    println!("{:20} {:>16} {:>12}", "profile", "mean best (us)", "worst (us)");
    let mut results = Vec::new();
    for (name, profile) in [
        ("full (paper)", KnowledgeProfile::Full),
        ("generic-only", KnowledgeProfile::GenericOnly),
        ("minimal", KnowledgeProfile::Minimal),
    ] {
        let mut bests = Vec::new();
        for seed in 0..SEEDS {
            let mut cfg = RunConfig::default().with_seed(seed).with_budget(BUDGET);
            cfg.knowledge = profile;
            let mut run = ScientistRun::new(cfg).expect("setup");
            bests.push(run.run_to_completion().expect("run").best_geomean_us);
        }
        let worst = bests.iter().cloned().fold(f64::MIN, f64::max);
        println!("{:20} {:>16.1} {:>12.1}", name, geomean(&bests), worst);
        results.push((name, geomean(&bests)));
    }
    // the paper's claim: digested knowledge is what lets the LLM loop
    // bridge the documentation gap — stripping it must hurt.
    assert!(
        results[0].1 < results[2].1,
        "full knowledge should beat minimal"
    );
    println!("\nknowledge ablation shape: OK (full < minimal)");
}
