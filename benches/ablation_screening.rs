//! Ablation: **analytic pre-screen tier on vs off** (DESIGN.md §10).
//!
//! The screen tier scores every planned candidate with the workload's
//! calibrated cost model — microseconds of arithmetic against ~90
//! simulated seconds for a platform submission — and only the top
//! keep-fraction of each rung ever occupies an evaluation lane. This
//! bench quantifies the multi-fidelity trade at an **equal submission
//! quota**:
//!
//! * **Assessment throughput.** Candidates assessed per unit simulated
//!   wall clock. The baseline assesses only what it submits; the
//!   screened run additionally assesses (and discards) every rejected
//!   candidate at analytic cost. Asserted ≥ 2x with `keep = 0.4`.
//! * **Solution quality.** Geomean-over-seeds best score must stay
//!   within 5% of the unscreened baseline — the tier rejects on the
//!   same cost surface the simulator measures, so pruning the slow
//!   half of each rung should not cost the optimizer its winners.
//!
//! Run: `cargo bench --bench ablation_screening`

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::metrics::geomean;
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::util::bench::header;
use gpu_kernel_scientist::workload::{self, Workload};

const SEEDS: u64 = 4;
const BUDGET: u64 = 60;
const LANES: u32 = 4;

struct Leg {
    best_us: f64,
    wall_clock_s: f64,
    submissions: u64,
    screened: u64,
    rejected: u64,
}

fn run_leg(seed: u64, screened: bool) -> Leg {
    let mut cfg = RunConfig::default()
        .with_seed(seed)
        .with_budget(BUDGET)
        .with_parallelism(LANES)
        .with_pipeline(true);
    if screened {
        cfg = cfg.with_screen(5, 0.4);
    }
    let mut run = ScientistRun::new(cfg).expect("setup");
    let outcome = run.run_to_completion().expect("run");
    Leg {
        best_us: outcome.best_geomean_us,
        wall_clock_s: outcome.wall_clock_s,
        submissions: outcome.submissions,
        screened: outcome.pipeline.screened,
        rejected: outcome.pipeline.screen_rejected,
    }
}

/// Candidates assessed per simulated hour: every submission is a
/// measured assessment; every screen rejection is an analytic one.
fn assess_rate(leg: &Leg) -> f64 {
    (leg.submissions + leg.rejected) as f64 / (leg.wall_clock_s / 3600.0)
}

fn main() {
    header("ablation — analytic pre-screen tier (multi-fidelity evaluation)");

    // seed submissions bypass the tier (they are evaluated before any
    // planning happens), so the conservation check needs their count
    let n_seeds = workload::registry()
        .into_iter()
        .find(|w| w.name() == RunConfig::default().workload)
        .expect("default workload is registered")
        .starting_population()
        .len() as u64;

    let mut base_best = Vec::new();
    let mut scr_best = Vec::new();
    let mut base_rates = Vec::new();
    let mut scr_rates = Vec::new();

    println!(
        "{:>6} {:>24} {:>32}",
        "seed", "baseline (best, rate/h)", "screened (best, rate/h, scored)"
    );
    for seed in 0..SEEDS {
        let base = run_leg(seed, false);
        let scr = run_leg(seed, true);
        assert_eq!(base.screened, 0, "baseline must not touch the tier");
        assert_eq!(
            scr.screened,
            (scr.submissions - n_seeds) + scr.rejected,
            "conservation: scored = promoted + rejected"
        );
        base_best.push(base.best_us);
        scr_best.push(scr.best_us);
        base_rates.push(assess_rate(&base));
        scr_rates.push(assess_rate(&scr));
        println!(
            "{seed:>6} {:>13.1} us {:>7.1} {:>13.1} us {:>7.1} {:>7}",
            base.best_us,
            assess_rate(&base),
            scr.best_us,
            assess_rate(&scr),
            scr.screened
        );
    }

    let rate_ratio = geomean(&scr_rates) / geomean(&base_rates);
    let best_ratio = geomean(&scr_best) / geomean(&base_best);
    println!(
        "\nassessment throughput: {rate_ratio:.2}x at equal quota ({BUDGET} submissions, {LANES} lanes)"
    );
    println!("best-score ratio (screened / baseline): {best_ratio:.3}");

    assert!(
        rate_ratio >= 2.0,
        "screening must at least double candidates assessed per unit \
         simulated wall clock (got {rate_ratio:.2}x)"
    );
    assert!(
        best_ratio <= 1.05,
        "screened best must stay within 5% of the unscreened baseline \
         (got {best_ratio:.3})"
    );
    println!("ablation_screening shape: OK");
}
