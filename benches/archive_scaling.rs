//! Perf bench: coordinator overhead at archive scale (DESIGN.md §Perf,
//! archive-scaling pass).
//!
//! The paper's loop re-reads the whole archive every planning round
//! ("strategically selecting promising prior code versions", §3.1).
//! Before the indexed archive, selection cloned + sorted `successful()`
//! and walked lineage by linear id scans — O(n)–O(n²) per round — so
//! coordinator overhead grew with ledger length exactly when long
//! campaigns made the ledger long. This bench drives synthetic archives
//! of 1k / 10k / 50k members through the real agent stages and asserts
//! the targets DESIGN.md §Perf records:
//!
//!   * per-planning-round coordinator cost (select → design → choose)
//!     grows ≤ 2x from 1k to 50k members;
//!   * the archive query mix (by_id, best, ancestors, config_winners,
//!     duplicate probe) grows ≤ 2x from 1k to 50k members;
//!   * journal-entry serialization streams allocation-free into a
//!     reusable buffer (reported as ns/entry; asserted ≤ 50 µs);
//!   * the federated archive (DESIGN.md §12) cold-loads a 50k-entry
//!     compacted segment ≥ 10x faster than parsing the same archive
//!     from JSONL (the segment path reads header + index only), and a
//!     sibling run's lookups hit 100% of the published fingerprints —
//!     written to `BENCH_federation.json` for the CI artifact.
//!
//! Run: `cargo bench --bench archive_scaling`

use std::time::Duration;

use gpu_kernel_scientist::agents::{AgentSuite, Designer, Selector};
use gpu_kernel_scientist::population::{EvalOutcome, Individual, Population};
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::rng::Rng;
use gpu_kernel_scientist::store::{
    federation, segment, ExperimentRecord, FedEntry, FederationSnapshot, JournalRecord,
};
use gpu_kernel_scientist::test_support::{random_genome, scratch_dir};
use gpu_kernel_scientist::util::bench::{bench, header, report, BenchResult};
use gpu_kernel_scientist::util::json::Json;
use gpu_kernel_scientist::workload::FEEDBACK_CONFIGS;

/// A realistic long-campaign archive: a branchy lineage forest over
/// mostly-recent parents, a slowly improving timing trend with
/// per-config jitter (so the "beats the best somewhere" frontier is a
/// bounded recent band at every archive size, as in real runs), ~8%
/// failures, and distinct-ish genomes from random edit walks.
fn synthetic_archive(n: usize, seed: u64) -> Population {
    let mut rng = Rng::seed_from_u64(seed);
    let mut pop = Population::new(FEEDBACK_CONFIGS.to_vec());
    for i in 0..n {
        let id = format!("{:05}", i + 1);
        let parents = if i == 0 {
            vec![]
        } else {
            // re-branching from mid-history winners keeps lineage depth
            // logarithmic in archive length (real archives re-branch
            // from the frontier, not from one ever-deepening chain) —
            // parent index in [i/2, i)
            let lo = i / 2;
            vec![format!("{:05}", lo + rng.below(i - lo) + 1)]
        };
        let outcome = if i > 0 && rng.chance(0.08) {
            EvalOutcome::CompileFailure("LDS overflow (synthetic)".into())
        } else {
            // multiplicative decay dominates the ±3% jitter beyond a
            // few hundred members, bounding the specialist frontier
            let trend = 5000.0 * 0.9997f64.powi(i as i32);
            EvalOutcome::Timings(
                (0..FEEDBACK_CONFIGS.len())
                    .map(|_| trend * rng.range_f64(0.97, 1.03))
                    .collect(),
            )
        };
        pop.add(Individual {
            id,
            parents,
            genome: random_genome(&mut rng),
            experiment: format!("synthetic experiment {i}"),
            report: String::new(),
            outcome,
        });
    }
    pop
}

struct SizePoint {
    n: usize,
    planning_round_ns: f64,
    query_mix_ns: f64,
}

fn measure(n: usize, budget: Duration) -> SizePoint {
    println!("\n-- archive of {n} members --");
    let pop = synthetic_archive(n, 42);
    let mut suite = AgentSuite::paper(7);
    let selector = Selector::new(SelectionPolicy::PaperLlm);
    let designer = Designer::default();

    // one full coordinator planning round against the ledger: the
    // selector's judgement (leaderboard top-k, specialist + divergence
    // candidates), the designer's 10 avenues → 5 plans, and the 3-of-5
    // choice. Everything but the writer/backend — i.e. exactly the
    // per-round overhead that used to scale with the archive.
    let r = bench("planning round (select → design → choose)", budget, || {
        let sel = selector.select(&pop, &mut suite.llm).expect("selects");
        let base = pop.by_id(&sel.base_id).expect("base in archive");
        let design = designer.design(
            &base.id,
            &base.genome,
            &pop,
            &suite.knowledge,
            &mut suite.llm,
            None,
        );
        let chosen = designer.choose(&design.plans, &mut suite.llm);
        std::hint::black_box((sel, chosen));
    });
    report(&r);
    let planning_round_ns = r.mean_ns;

    // the raw archive query mix every consumer leans on
    let probe = pop.members()[n / 2].genome.clone();
    let novel = {
        // a genome absent from the archive: flip until the probe misses
        let mut rng = Rng::seed_from_u64(987);
        loop {
            let g = random_genome(&mut rng);
            if pop.find_duplicate(&g).is_none() {
                break g;
            }
        }
    };
    let deep_id = pop.members()[n - 1].id.clone();
    let q = bench("query mix (by_id/best/ancestors/winners/dup)", budget, || {
        std::hint::black_box(pop.by_id(&deep_id));
        std::hint::black_box(pop.best());
        std::hint::black_box(pop.ancestors(&deep_id).len());
        std::hint::black_box(pop.config_winners());
        std::hint::black_box(pop.find_duplicate(&probe).is_some());
        std::hint::black_box(pop.find_duplicate(&novel).is_none());
    });
    report(&q);
    SizePoint {
        n,
        planning_round_ns,
        query_mix_ns: q.mean_ns,
    }
}

fn journal_serialization(budget: Duration) -> BenchResult {
    let pop = synthetic_archive(64, 5);
    let records: Vec<JournalRecord> = pop
        .members()
        .iter()
        .enumerate()
        .map(|(i, m)| {
            JournalRecord::Exp(ExperimentRecord {
                individual: m.clone(),
                submitted_at: i as u64 + 1,
                submission_index: Some(i as u64),
                cached: false,
                lane: Some((i % 4) as u32),
                completed_at_s: Some(90.0 * (i as f64 + 1.0)),
                plan: if i > 2 { Some(i / 3) } else { None },
                screened: i % 2 == 0,
                profile: None,
                federated: false,
                lint: Vec::new(),
            })
        })
        .collect();
    let mut buf = String::new();
    let mut i = 0usize;
    let r = bench("journal entry streamed serialize (reused buffer)", budget, || {
        buf.clear();
        records[i % records.len()].write_json(&mut buf);
        buf.push('\n');
        std::hint::black_box(buf.len());
        i += 1;
    });
    report(&r);
    r
}

/// The federated-archive scaling pass (DESIGN.md §12): a 50k-entry
/// archive cold-loaded from JSONL (full parse: every genome object)
/// vs from its compacted segment index (header + fingerprint/offset
/// table only, CRC-checked) — the O(n-parse) vs O(index) claim — plus
/// the cross-run hit rate a sibling run sees against the published
/// fingerprints. Results land in `BENCH_federation.json`.
fn federation_scaling(budget: Duration) {
    const N: usize = 50_000;
    println!("\n-- federated archive of {N} entries --");
    let mut rng = Rng::seed_from_u64(77);
    let digest = 0x00c0_ffee_0bad_f00du64;
    let entries: Vec<FedEntry> = (0..N)
        .map(|i| {
            let genome = random_genome(&mut rng);
            FedEntry {
                workload: "fp8-gemm".into(),
                digest,
                // synthetic distinct fingerprints: collisions in the
                // random-walk genomes must not shrink the archive
                fingerprint: i as u64 + 1,
                genome,
                outcome: EvalOutcome::Timings(vec![rng.range_f64(300.0, 5000.0); 6]),
            }
        })
        .collect();
    let dir = scratch_dir("bench-federation");
    federation::write_run_results(&dir, "fp8-gemm", 1, digest, &entries)
        .expect("write archive");

    let jsonl = bench("archive cold-load, JSONL full parse", budget, || {
        let snap = FederationSnapshot::load(&dir).expect("jsonl load");
        std::hint::black_box(snap.len());
    });
    report(&jsonl);

    let compacted = federation::compact_dir(&dir).expect("compact");
    assert_eq!(compacted, 1);
    let seg_path = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.path()))
        .find(|p| p.extension().and_then(|e| e.to_str()) == Some("seg"))
        .expect("segment file");
    let seg = bench("archive cold-load, segment index only", budget, || {
        let idx = segment::open_index(&seg_path).expect("segment open");
        std::hint::black_box(idx.entries.len());
    });
    report(&seg);

    // a sibling run consults the snapshot once, then probes per genome:
    // every published fingerprint must hit, absent ones must miss
    let snap = FederationSnapshot::load(&dir).expect("segment snapshot load");
    let results = snap.results_for("fp8-gemm", digest);
    let mut hits = 0usize;
    for fp in 1..=N as u64 {
        if results.contains_key(&fp) {
            hits += 1;
        }
    }
    let absent = ((N as u64 + 1)..=(N as u64 + 5_000)).filter(|fp| results.contains_key(fp)).count();
    let hit_rate = hits as f64 / N as f64;
    let speedup = jsonl.mean_ns / seg.mean_ns;
    println!(
        "\ncold-load at {N} entries: jsonl {:.1} ms, segment {:.2} ms — {speedup:.1}x \
         (target >= 10x); cross-run hit rate {:.1}% (target 100%)",
        jsonl.mean_ns / 1e6,
        seg.mean_ns / 1e6,
        hit_rate * 100.0
    );
    assert!(
        speedup >= 10.0,
        "segment cold-load must be >= 10x faster than JSONL parse at {N} entries \
         (got {speedup:.1}x)"
    );
    assert_eq!(hits, N, "every published fingerprint must be servable");
    assert_eq!(absent, 0, "unpublished fingerprints must never hit");

    let doc = Json::obj(vec![
        ("entries", Json::Num(N as f64)),
        ("jsonl_cold_load_ms", Json::Num(jsonl.mean_ns / 1e6)),
        ("segment_cold_load_ms", Json::Num(seg.mean_ns / 1e6)),
        ("segment_speedup", Json::Num(speedup)),
        ("cross_run_hit_rate", Json::Num(hit_rate)),
    ]);
    std::fs::write("BENCH_federation.json", doc.to_string()).expect("write BENCH_federation.json");
    println!("federation scaling written to BENCH_federation.json");
}

fn main() {
    header("archive_scaling — coordinator overhead vs ledger length");
    let budget = Duration::from_millis(400);

    // two interleaved measurement rounds, per-size minimum: a noisy
    // neighbour on a shared CI runner inflates one window, not the
    // min of two windows taken seconds apart — the asserted ratios
    // compare like against like
    let sizes = [1_000usize, 10_000, 50_000];
    let mut points: Vec<SizePoint> = sizes.into_iter().map(|n| measure(n, budget)).collect();
    println!("\n-- second interleaved round (per-size minimum is scored) --");
    for (i, n) in sizes.into_iter().enumerate() {
        let again = measure(n, budget);
        points[i].planning_round_ns = points[i].planning_round_ns.min(again.planning_round_ns);
        points[i].query_mix_ns = points[i].query_mix_ns.min(again.query_mix_ns);
    }

    println!("\n| members | planning round | query mix |");
    println!("|--------:|---------------:|----------:|");
    for p in &points {
        println!(
            "| {:6} | {:11.1} us | {:7.2} us |",
            p.n,
            p.planning_round_ns / 1e3,
            p.query_mix_ns / 1e3
        );
    }

    let small = &points[0];
    let large = &points[points.len() - 1];
    let plan_ratio = large.planning_round_ns / small.planning_round_ns;
    let query_ratio = large.query_mix_ns / small.query_mix_ns;
    println!(
        "\n1k → 50k growth: planning {plan_ratio:.2}x, query mix {query_ratio:.2}x \
         (target <= 2x each)"
    );
    assert!(
        plan_ratio <= 2.0,
        "planning-round overhead must stay near-flat (1k → 50k grew {plan_ratio:.2}x)"
    );
    assert!(
        query_ratio <= 2.0,
        "archive query mix must stay near-flat (1k → 50k grew {query_ratio:.2}x)"
    );
    // absolute sanity alongside sim_throughput's 5 ms/iteration bound:
    // a planning round against a 50k-member ledger stays far below the
    // 90 s/submission platform latency it schedules against
    assert!(
        large.planning_round_ns < 5_000_000.0,
        "planning round at 50k members above 5 ms: {} ns",
        large.planning_round_ns
    );

    let j = journal_serialization(budget);
    assert!(
        j.mean_ns < 50_000.0,
        "journal entry serialization above 50 us: {} ns",
        j.mean_ns
    );

    federation_scaling(budget);

    println!("\narchive_scaling targets: OK");
}
