//! Ablation: **the fault-recovery policy on vs off under chaos**
//! (DESIGN.md §14).
//!
//! Three legs at an equal submission quota (60 submissions, 4 lanes,
//! pipeline scheduler):
//!
//!   * **clean** — fault model off: the PR-9 baseline;
//!   * **recovery** — faults injected, recovery on: transient errors
//!     retry with capped backoff, straggler timeouts and suspect
//!     timings requeue, so every planned experiment still resolves;
//!   * **no-recovery** — the same chaos with the policy off: every
//!     fault-class completion abandons its experiment on the spot.
//!
//! Asserted across seeds:
//!
//!   * the recovery leg commits the clean leg's full quota — chaos
//!     costs retries, never the submission budget;
//!   * the no-recovery legs abandon a nonzero number of experiments
//!     and strictly more than the recovery legs — recovery is what
//!     turns losses into retries;
//!   * the recovery leg's best score stays within 5% of the clean
//!     baseline (geomean of per-seed ratios) — the salvaged retries
//!     keep the optimization trajectory intact.
//!
//! Results land in `BENCH_faults.json` for the CI artifact.
//!
//! Run: `cargo bench --bench ablation_faults`

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::metrics::geomean;
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::util::bench::header;
use gpu_kernel_scientist::util::json::Json;

const SEEDS: u64 = 6;
const BUDGET: u64 = 60;
const LANES: u32 = 4;

struct Leg {
    submissions: u64,
    best_us: f64,
    injected: u64,
    retries: u64,
    abandoned: u64,
}

fn run_leg(seed: u64, faults: bool, recovery: bool) -> Leg {
    let mut cfg = RunConfig::default()
        .with_seed(seed)
        .with_budget(BUDGET)
        .with_parallelism(LANES)
        .with_pipeline(true);
    if faults {
        // chaos hot enough to bite every leg, mild enough that the
        // recovery leg's salvage keeps the trajectory intact
        cfg.faults.enabled = true;
        cfg.faults.transient = 0.10;
        cfg.faults.straggler = 0.06;
        cfg.faults.corrupt = 0.06;
        cfg.faults.lane_death = 0.0;
        cfg.faults.backoff_base_s = 5.0;
        cfg.faults.quarantine_after = 10;
        cfg.faults.recovery = recovery;
    }
    let mut run = ScientistRun::new(cfg).expect("setup");
    let outcome = run.run_to_completion().expect("run");
    let summary = outcome.faults.unwrap_or_default();
    Leg {
        submissions: outcome.submissions,
        best_us: outcome.best_geomean_us,
        injected: summary.stats.injected(),
        retries: summary.retries,
        abandoned: summary.abandoned,
    }
}

fn main() {
    header("ablation — fault recovery under chaos (equal submission quota)");

    let mut ratios = Vec::new();
    let mut injected_total = 0u64;
    let mut recovery_abandoned = 0u64;
    let mut norec_abandoned = 0u64;

    println!(
        "{:>6} {:>12} {:>24} {:>24}",
        "seed", "clean best", "recovery (inj/retry/ab)", "no-recovery (inj/ab)"
    );
    for seed in 0..SEEDS {
        let clean = run_leg(seed, false, true);
        let rec = run_leg(seed, true, true);
        let norec = run_leg(seed, true, false);
        assert_eq!(
            rec.submissions, clean.submissions,
            "seed {seed}: the recovery leg lost quota to chaos"
        );
        assert_eq!(
            norec.retries, 0,
            "seed {seed}: a no-recovery leg retried"
        );
        injected_total += rec.injected + norec.injected;
        recovery_abandoned += rec.abandoned;
        norec_abandoned += norec.abandoned;
        let ratio = rec.best_us / clean.best_us;
        ratios.push(ratio);
        println!(
            "{seed:>6} {:>10.1}us {:>10}/{}/{} {:>18}/{}   (ratio {ratio:.3})",
            clean.best_us, rec.injected, rec.retries, rec.abandoned,
            norec.injected, norec.abandoned,
        );
    }

    let margin = geomean(&ratios);
    println!(
        "\nbest-score ratio recovery/clean at equal quota ({BUDGET} submissions, \
         {LANES} lanes): geomean {margin:.3} (target <= 1.05) — abandoned: \
         recovery {recovery_abandoned} vs no-recovery {norec_abandoned}"
    );
    assert!(
        injected_total > 0,
        "no leg saw a fault across {SEEDS} seeds — raise the chaos knobs"
    );
    assert!(
        norec_abandoned > 0,
        "no-recovery legs abandoned nothing: the ablation shows no contrast"
    );
    assert!(
        recovery_abandoned < norec_abandoned,
        "recovery must strictly reduce abandoned experiments \
         ({recovery_abandoned} vs {norec_abandoned})"
    );
    assert!(
        margin <= 1.05,
        "recovery must keep the best score within 5% of fault-free \
         (got {margin:.3})"
    );

    let doc = Json::obj(vec![
        ("seeds", Json::Num(SEEDS as f64)),
        ("budget", Json::Num(BUDGET as f64)),
        ("lanes", Json::Num(LANES as f64)),
        ("injected_total", Json::Num(injected_total as f64)),
        ("recovery_abandoned", Json::Num(recovery_abandoned as f64)),
        ("norec_abandoned", Json::Num(norec_abandoned as f64)),
        ("best_ratio_geomean", Json::Num(margin)),
    ]);
    std::fs::write("BENCH_faults.json", doc.to_string()).expect("write BENCH_faults.json");
    println!("faults ablation written to BENCH_faults.json");
    println!("ablation_faults shape: OK");
}
