//! Ablation: the **bootstrap hardware-probing phase** (paper §4.1,
//! §4.3, footnote 2).
//!
//! The paper's system spends an "extended deep-dive" discovering MFMA
//! semantics by probing the platform before the loop starts, distilled
//! into the findings document. Three arms:
//!   assumed  — findings pre-distilled (the loop's steady state);
//!   probed   — findings re-derived by platform probes (costs 3
//!              submissions out of the same budget);
//!   none     — no bootstrap ever ran: the MFMA / LDS-trick avenues
//!              stay gated off (what §4.1 calls the documentation gap).
//!
//! Run: `cargo bench --bench ablation_bootstrap`

use gpu_kernel_scientist::agents::FindingsDoc;
use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::metrics::geomean;
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::util::bench::header;

fn main() {
    header("ablation — bootstrap probing (findings provenance)");
    const SEEDS: u64 = 5;
    const BUDGET: u64 = 100;
    println!("{:28} {:>16} {:>12}", "arm", "mean best (us)", "worst (us)");

    let mut results = Vec::new();
    for arm in ["assumed", "probed", "none"] {
        let mut bests = Vec::new();
        for seed in 0..SEEDS {
            let mut cfg = RunConfig::default().with_seed(seed).with_budget(BUDGET);
            cfg.bootstrap_probing = arm == "probed";
            // the MFMA seed is itself a bootstrap product
            cfg.include_mfma_seed = arm != "none";
            let mut run = ScientistRun::new(cfg).expect("setup");
            if arm == "none" {
                // wipe the findings: gated avenues never unlock
                run.agents.knowledge.findings = FindingsDoc::default();
            }
            bests.push(run.run_to_completion().expect("run").best_geomean_us);
        }
        let worst = bests.iter().cloned().fold(f64::MIN, f64::max);
        println!("{:28} {:>16.1} {:>12.1}", arm, geomean(&bests), worst);
        results.push((arm, geomean(&bests)));
    }
    let assumed = results[0].1;
    let probed = results[1].1;
    let none = results[2].1;
    println!(
        "\nprobing overhead vs assumed findings: {:+.1}% (3 probe submissions)",
        (probed / assumed - 1.0) * 100.0
    );
    println!(
        "never bootstrapping costs {:.1}x (the MFMA avenue stays locked)",
        none / assumed
    );
    assert!(
        none > assumed * 1.5,
        "bootstrap findings must matter: none={none:.0} assumed={assumed:.0}"
    );
    println!("ablation_bootstrap shape: OK");
}
