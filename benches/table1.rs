//! Bench: regenerate **Table 1** of the paper (AMD Developer Challenge
//! summary results) — the headline evaluation artifact.
//!
//! Rows: PyTorch reference, Human 1st place, Naive HIP (canonical
//! genomes on the simulated MI300), plus "This work" produced by an
//! actual scientist run at the paper's sequential budget, over several
//! seeds. Shape assertions (who wins, rough factors) run at the end.
//!
//! Run: `cargo bench --bench table1`

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::gpu::MI300;
use gpu_kernel_scientist::metrics::geomean;
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::report::{render_table, TableRow};
use gpu_kernel_scientist::sim::calibration;
use gpu_kernel_scientist::util::bench::header;

fn main() {
    header("table1 — AMD Developer Challenge summary results");
    const SEEDS: u64 = 5;
    const BUDGET: u64 = 120;

    let mut this_work = Vec::new();
    for seed in 0..SEEDS {
        let cfg = RunConfig::default().with_seed(seed).with_budget(BUDGET);
        let mut run = ScientistRun::new(cfg).expect("setup");
        let outcome = run.run_to_completion().expect("run");
        let lb = outcome.leaderboard_us.expect("leaderboard");
        println!(
            "  seed {seed}: best {} feedback {:.1} us, leaderboard {:.1} us, {} submissions",
            outcome.best_id, outcome.best_geomean_us, lb, outcome.submissions
        );
        this_work.push(lb);
    }
    let this_us = geomean(&this_work);

    let mut rows: Vec<TableRow> = calibration::table1_rows(&MI300)
        .into_iter()
        .filter(|(l, _, _)| !l.starts_with("This work"))
        .map(|(label, paper, sim)| TableRow {
            label: label.to_string(),
            paper_us: Some(paper),
            measured_us: sim,
            comment: match label {
                "PyTorch reference" => "uses library fp16".into(),
                "Human 1st place" => "top-8 had access to actual MI300".into(),
                _ => "unoptimized".into(),
            },
        })
        .collect();
    rows.push(TableRow {
        label: "This work".into(),
        paper_us: Some(450.0),
        measured_us: this_us,
        comment: format!("LLM-only, geomean of {SEEDS} seeds x {BUDGET} submissions"),
    });
    println!();
    println!(
        "{}",
        render_table("Table 1 — AMD Developer Challenge summary results", &rows)
    );

    let lib = rows[0].measured_us;
    let oracle = rows[1].measured_us;
    let naive = rows[2].measured_us;
    println!("shape checks (paper ratios in parens):");
    println!("  naive/pytorch = {:5.1}x  (~5.9x)", naive / lib);
    println!("  pytorch/this  = {:5.1}x  (~1.9x)", lib / this_us);
    println!("  this/oracle   = {:5.2}x  (~4.3x)", this_us / oracle);
    assert!(naive > lib && lib > this_us && oracle < this_us * 1.10);
    println!("\ntable1 shape: OK");
}
