use gpu_kernel_scientist::runtime::PjrtBackend;
use gpu_kernel_scientist::workload::GemmConfig;
use std::path::Path;

fn main() {
    let mut b = PjrtBackend::open(Path::new("artifacts")).unwrap();
    b.inner_reps = 3;
    let cfg = GemmConfig::new(256, 256, 256);
    let ref_name = b.catalog().reference_for(&cfg).unwrap().name.clone();
    let ref_us = b.time_entry(&ref_name, &cfg).unwrap();
    println!("ref: {ref_us:.1} us");
    for name in ["g128x256x128_fs_sc_ki_m256k256n256",
                 "g256x256x128_fs_sc_ki_m256k256n256",
                 "g256x256x256_fs_sc_ki_m256k256n256"] {
        b.verify(name, &cfg).unwrap();
        let us = b.time_entry(name, &cfg).unwrap();
        println!("{name}: {us:.1} us ({:.2}x of ref)", ref_us / us);
    }
}
