//! Scientist vs classic tuners at an equal submission budget.
//!
//! The paper argues (§2) that OpenTuner/Kernel-Tuner-style search is
//! complementary but narrower than LLM-driven experimentation. This
//! driver runs the scientist and three baseline tuners over the SAME
//! genome space on the SAME simulated platform with the SAME budget.
//!
//! Run: `cargo run --release --example baseline_shootout [budget] [seeds]`

use gpu_kernel_scientist::baselines::{Annealer, GeneticAlgorithm, HillClimber, RandomSearch, Tuner};
use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::eval::{EvalPlatform, PlatformConfig};
use gpu_kernel_scientist::metrics::geomean;
use gpu_kernel_scientist::prelude::*;

fn main() {
    let budget: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);
    let n_seeds: u64 = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);

    println!("strategy shootout: budget {budget} submissions, {n_seeds} seeds\n");
    println!("{:24} {:>14} {:>14}", "strategy", "mean best (us)", "worst (us)");

    let mut rows: Vec<(&str, Vec<f64>)> = Vec::new();

    let mut scientist = Vec::new();
    for seed in 0..n_seeds {
        let cfg = RunConfig::default().with_seed(seed).with_budget(budget);
        let mut run = ScientistRun::new(cfg).expect("setup");
        scientist.push(run.run_to_completion().expect("run").best_geomean_us);
    }
    rows.push(("scientist (paper)", scientist));

    for which in ["random", "hillclimb", "anneal", "genetic"] {
        let mut bests = Vec::new();
        for seed in 0..n_seeds {
            let mut platform = EvalPlatform::new(
                SimBackend::new(seed),
                PlatformConfig {
                    submission_quota: Some(budget),
                    ..Default::default()
                },
            );
            let out = match which {
                "random" => RandomSearch { seed }.run(&mut platform, budget),
                "hillclimb" => HillClimber {
                    seed,
                    ..Default::default()
                }
                .run(&mut platform, budget),
                "anneal" => Annealer {
                    seed,
                    ..Default::default()
                }
                .run(&mut platform, budget),
                _ => GeneticAlgorithm {
                    seed,
                    ..Default::default()
                }
                .run(&mut platform, budget),
            };
            bests.push(out.best_geomean_us);
        }
        let name = match which {
            "random" => "random search",
            "hillclimb" => "hill climber",
            "anneal" => "simulated annealing",
            _ => "genetic algorithm (Evolver)",
        };
        rows.push((name, bests));
    }

    for (name, bests) in &rows {
        let worst = bests.iter().cloned().fold(f64::MIN, f64::max);
        println!("{:24} {:>14.1} {:>14.1}", name, geomean(bests), worst);
    }

    let scientist_mean = geomean(&rows[0].1);
    for (name, bests) in rows.iter().skip(1) {
        let m = geomean(bests);
        println!(
            "scientist vs {:20}: {:.2}x {}",
            name,
            (m / scientist_mean).max(scientist_mean / m),
            if scientist_mean <= m { "faster" } else { "slower" }
        );
    }
}
