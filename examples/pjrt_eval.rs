//! PJRT end-to-end: the scientist loop driving *real compiled kernels*.
//!
//! Proves the three layers compose: L1 Pallas fp8 GEMM variants were
//! AOT-lowered (python, build time) to `artifacts/*.hlo.txt`; this
//! binary loads them via the `xla` PJRT CPU client (L3), verifies them
//! against the compiled reference path, and runs the *same* scientist
//! loop with wall-clock timings as the only feedback.
//!
//! Needs `make artifacts` first. Run:
//! `cargo run --release --example pjrt_eval [budget]`

use std::path::Path;

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::eval::{EvalPlatform, PlatformConfig};
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::runtime::PjrtBackend;
use gpu_kernel_scientist::workload::GemmConfig;

fn main() {
    let budget: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);

    let mut backend = PjrtBackend::open(Path::new("artifacts")).expect(
        "artifacts/catalog.json missing — run `make artifacts` first",
    );
    backend.inner_reps = 1;

    // 1) verify + time every compiled variant on the primary shape
    let cfg = GemmConfig::new(256, 256, 256);
    println!("== catalog verification on {cfg} (vs compiled reference path) ==");
    let ref_us = {
        let name = backend.catalog().reference_for(&cfg).unwrap().name.clone();
        backend.time_entry(&name, &cfg).expect("reference timing")
    };
    println!("  {:45} {ref_us:10.1} us  (library path)", "ref");
    let names: Vec<(String, Option<u64>)> = backend
        .catalog()
        .variants_for(&cfg)
        .iter()
        .map(|e| (e.name.clone(), e.vmem_bytes))
        .collect();
    let mut best: Option<(String, f64)> = None;
    for (name, vmem) in names {
        match backend.verify(&name, &cfg) {
            Ok(()) => {
                let us = backend.time_entry(&name, &cfg).expect("timing");
                println!(
                    "  {name:45} {us:10.1} us  (VMEM {:.0} KiB)",
                    vmem.unwrap_or(0) as f64 / 1024.0
                );
                if best.as_ref().map(|(_, b)| us < *b).unwrap_or(true) {
                    best = Some((name, us));
                }
            }
            Err(e) => println!("  {name:45} FAILED: {e}"),
        }
    }
    let (best_name, best_us) = best.expect("some variant timed");
    println!("\nbest variant: {best_name} at {best_us:.1} us ({:.2}x vs library path)", ref_us / best_us);

    // 2) the same scientist loop, but the evaluation platform times
    //    real compiled kernels (CPU-testbed shapes)
    println!("\n== scientist loop over the PJRT backend (budget {budget}) ==");
    let platform = EvalPlatform::new(
        backend,
        PlatformConfig {
            reps_per_config: 1,
            parallelism: 1,
            submission_quota: Some(budget),
            ..Default::default()
        },
    )
    .with_feedback_suite(BenchmarkSuite {
        name: "pjrt-primary".into(),
        configs: vec![cfg],
    });
    let cfg_run = RunConfig::default().with_seed(7).with_budget(budget);
    let mut run =
        ScientistRun::with_platform(cfg_run, platform).expect("pjrt scientist setup");
    let outcome = run.run_to_completion().expect("pjrt run");
    println!(
        "best individual {}: {:.1} us measured over PJRT after {} submissions",
        outcome.best_id, outcome.best_geomean_us, outcome.submissions
    );
    for m in run.population.members() {
        let score = m
            .score()
            .map(|s| format!("{s:10.1} us"))
            .unwrap_or_else(|| format!("{:?}", m.outcome));
        println!("  {}  {:55}  {}", m.id, truncate(&m.experiment, 55), score);
    }
    println!("\nall three layers composed: pallas (L1) -> jax AOT (L2) -> rust PJRT loop (L3)");
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}...", &s[..n - 3])
    }
}
