// Dev utility: exhaustively hill-climb to the search-space optimum, to
// pin the human-oracle genome at the true noiseless bound.
use gpu_kernel_scientist::baselines::oracle_search;
use gpu_kernel_scientist::gpu::MI300;
use gpu_kernel_scientist::workload::LEADERBOARD_SIZES;

fn main() {
    let mut best_overall: Option<(f64, _)> = None;
    for seed in 0..8 {
        let (score, g) = oracle_search(&MI300, &LEADERBOARD_SIZES, 40, seed);
        println!("seed {seed}: {score:.2} us");
        if best_overall.as_ref().map(|(b, _)| score < *b).unwrap_or(true) {
            best_overall = Some((score, g));
        }
    }
    let (score, g) = best_overall.unwrap();
    println!("\nbest: {score:.2} us\n{g:#?}");
}
