//! End-to-end driver (the DESIGN.md §6 validation run).
//!
//! Runs the complete GPU Kernel Scientist loop — 3 seed kernels, ~120
//! sequential submissions to the simulated MI300 evaluation platform —
//! then regenerates Table 1 and the convergence series, and prints the
//! per-iteration transcript tail. EXPERIMENTS.md records this run.
//!
//! Run: `cargo run --release --example full_run [seed] [budget]`

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::gpu::MI300;
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::report::{self, TableRow};
use gpu_kernel_scientist::sim::calibration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seed: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(0);
    let budget: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(120);

    println!("GPU Kernel Scientist — full run (seed {seed}, budget {budget})\n");
    let cfg = RunConfig::default().with_seed(seed).with_budget(budget);
    let mut run = ScientistRun::new(cfg).expect("setup");
    let outcome = run.run_to_completion().expect("run");

    // --- the paper's Figure-1 loop transcript (tail) ---
    println!("== last three iterations ==\n");
    for log in run.logs.iter().rev().take(3).collect::<Vec<_>>().into_iter().rev() {
        println!("{}", report::render_iteration(log));
    }

    // --- Table 1 ---
    let mut rows: Vec<TableRow> = calibration::table1_rows(&MI300)
        .into_iter()
        .filter(|(l, _, _)| !l.starts_with("This work"))
        .map(|(label, paper, sim)| TableRow {
            label: label.to_string(),
            paper_us: Some(paper),
            measured_us: sim,
            comment: match label {
                "PyTorch reference" => "uses library fp16".into(),
                "Human 1st place" => "top-8 had access to actual MI300".into(),
                _ => "unoptimized".into(),
            },
        })
        .collect();
    rows.push(TableRow {
        label: "This work".into(),
        paper_us: Some(450.0),
        measured_us: outcome.leaderboard_us.unwrap_or(outcome.best_geomean_us),
        comment: format!("LLM-only ({} submissions)", outcome.submissions),
    });
    println!(
        "{}",
        report::render_table("Table 1 — AMD Developer Challenge summary results", &rows)
    );

    // --- shape checks the paper's narrative implies ---
    let lib = rows[0].measured_us;
    let naive = rows[2].measured_us;
    let this_work = rows[3].measured_us;
    let oracle = rows[1].measured_us;
    println!("ratios: naive/pytorch = {:.1}x (paper ~5.9x)", naive / lib);
    println!("        pytorch/this  = {:.1}x (paper ~1.9x)", lib / this_work);
    println!("        this/oracle   = {:.2}x (paper ~4.3x => oracle leads)", this_work / oracle);
    assert!(naive > lib, "naive must lose to the library");
    assert!(this_work < lib, "the scientist must beat the library");
    assert!(oracle < this_work * 1.10, "the human oracle stays ahead (within noise)");

    // --- convergence (the Figure-1 loop's observable output) ---
    println!(
        "{}",
        report::render_convergence("scientist best-so-far", &outcome.curve)
    );
    println!(
        "platform time: {:.1} simulated hours across {} sequential submissions",
        outcome.wall_clock_s / 3600.0,
        outcome.submissions
    );

    // --- best kernel anatomy ---
    let best = run.population.by_id(&outcome.best_id).unwrap();
    println!("\n== best kernel {} ==", best.id);
    println!("{}", best.experiment);
    println!(
        "{}",
        gpu_kernel_scientist::genome::render::render_hip_sketch(&best.genome)
    );
}
