//! Quickstart: one loop iteration, narrated.
//!
//! Shows the three agent stages exactly as the paper's appendices do:
//! the selector's rationale (App. A.1), the designer's avenues + 5
//! plans with performance/innovation estimates and the 3-of-5 choice
//! (App. A.2), and the writer's kernel + self-report (App. A.3).
//!
//! Run: `cargo run --example quickstart`
//!
//! The loop is workload-generic; pass any registry key to watch it
//! optimize a different kernel family (the CI smoke matrix runs all):
//! `cargo run --example quickstart -- --workload row-softmax`

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::genome::render;
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::report;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let workload = args
        .iter()
        .position(|a| a == "--workload")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or(gpu_kernel_scientist::workload::DEFAULT_WORKLOAD);
    let cfg = RunConfig::default()
        .with_seed(42)
        .with_budget(30)
        .with_workload(workload);
    let mut run = ScientistRun::new(cfg).expect("run setup");

    println!("== workload: {} ==", run.workload.description());
    println!("\n== population after seeding (paper §3) ==");
    for m in run.population.members() {
        println!(
            "  {}  {:60}  geomean {:8.1} us",
            m.id,
            m.experiment,
            m.score().unwrap_or(f64::NAN)
        );
    }

    // a couple of warmup iterations so lineage exists
    for _ in 0..3 {
        run.run_iteration();
    }

    println!("\n== one full iteration, narrated ==\n");
    let log = run.run_iteration().expect("iteration");
    println!("{}", report::render_iteration(log));

    let base_id = log.selection.base_id.clone();
    let submitted: Vec<String> = log.submitted_ids.clone();
    let base = run.population.by_id(&base_id).unwrap().clone();
    println!("== base kernel listing (genome rendered as HIP sketch) ==\n");
    println!("{}", render::render_hip_sketch(&base.genome));

    for id in &submitted {
        let child = run.population.by_id(id).unwrap();
        println!("== child {} ==", child.id);
        println!("{}", child.report);
        match child.score() {
            Some(s) => println!("feedback geomean: {s:.1} us\n"),
            None => println!("outcome: {:?}\n", child.outcome),
        }
    }

    let outcome = run.run_to_completion().expect("completion");
    println!(
        "after {} submissions: best {} at {:.1} us (started from {:.1} us)",
        outcome.submissions,
        outcome.best_id,
        outcome.best_geomean_us,
        run.population.by_id("00001").unwrap().score().unwrap()
    );
    println!("convergence: {}", outcome.curve.ascii_sparkline(50));
}
