use gpu_kernel_scientist::genome::seeds;
use gpu_kernel_scientist::gpu::MI300;
use gpu_kernel_scientist::sim::{calibration, estimate};
use gpu_kernel_scientist::workload::{GemmConfig, LEADERBOARD_SIZES};

fn main() {
    for (label, paper, sim) in calibration::table1_rows(&MI300) {
        println!("{label:40} paper {paper:7.0}  sim {sim:9.1}");
    }
    println!();
    for (name, g) in seeds::all_seeds() {
        let cfg = GemmConfig::new(6144, 512, 4096);
        let t = estimate(&MI300, &g, &cfg).unwrap();
        println!("{name:20} {cfg}: total {:9.1}  comp {:8.1} (ldsx{:.2}) mem {:8.1} wb {:7.1} launch {:5.1} occ_w {:2} util {:.2}",
            t.total_us, t.compute_us, t.lds_pressure, t.mem_us, t.writeback_us, t.launch_us, t.occupancy_waves, t.grid_utilization);
        let big = LEADERBOARD_SIZES[14];
        let t = estimate(&MI300, &g, &big).unwrap();
        println!("{name:20} {big}: total {:9.1}  comp {:8.1} (ldsx{:.2}) mem {:8.1} wb {:7.1} launch {:5.1} occ_w {:2} util {:.2}",
            t.total_us, t.compute_us, t.lds_pressure, t.mem_us, t.writeback_us, t.launch_us, t.occupancy_waves, t.grid_utilization);
    }
}
