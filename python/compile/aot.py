"""AOT compile path: lower the L2 graph (per kernel variant) to HLO text.

Emits ``artifacts/<name>.hlo.txt`` plus ``artifacts/catalog.json`` which
the rust PJRT runtime (``rust/src/runtime``) reads to discover variants.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids, so text round-trips cleanly.

The catalog covers a *projection* of the full kernel genome (see
DESIGN.md §2): tile sizes, scale fusion, accumulator placement, grid
walk. Shapes are CPU-testbed scale (the real 6144x512x4096-class
configs are simulator-only).
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import pathlib
import sys

import jax
from jax._src.lib import xla_client as xc

from compile.kernels.fp8_gemm import GemmVariant
from compile import model

#: Testbed shapes (m, k, n). Small enough that the interpret-lowered
#: grid while-loop stays fast on the CPU PJRT client, large enough that
#: tile-size choices change the measured time.
SHAPES: list[tuple[int, int, int]] = [
    (256, 256, 256),
    (512, 256, 256),
    (256, 512, 512),
]

#: The genome projections compiled into the catalog. "naive" mirrors the
#: paper's direct-translation seed (tiny tiles, no private accumulator,
#: k-outermost walk); "evolved" mirrors the App. A.3 kernel structure.
VARIANTS: list[GemmVariant] = [
    # naive-translation seed: k-outermost, acc in output, unfused
    GemmVariant(32, 32, 32, fuse_scales=False, acc_in_scratch=False,
                k_innermost=False),
    # intermediate points on the evolution path
    GemmVariant(32, 32, 32, fuse_scales=True, acc_in_scratch=False,
                k_innermost=True),
    GemmVariant(64, 64, 32, fuse_scales=True, acc_in_scratch=True),
    GemmVariant(64, 64, 64, fuse_scales=False, acc_in_scratch=True),
    GemmVariant(64, 64, 64, fuse_scales=True, acc_in_scratch=True),
    GemmVariant(128, 64, 64, fuse_scales=True, acc_in_scratch=True),
    GemmVariant(64, 128, 64, fuse_scales=True, acc_in_scratch=True),
    GemmVariant(128, 128, 64, fuse_scales=True, acc_in_scratch=True),
    GemmVariant(128, 128, 128, fuse_scales=True, acc_in_scratch=True),
    GemmVariant(128, 128, 256, fuse_scales=True, acc_in_scratch=True),
    GemmVariant(256, 128, 64, fuse_scales=True, acc_in_scratch=True),
    GemmVariant(128, 256, 128, fuse_scales=True, acc_in_scratch=True),
    # Perf-pass variants (EXPERIMENTS.md §Perf, L1 iteration 1): on the
    # CPU testbed the interpret-lowered grid becomes an XLA while-loop,
    # so fewer/larger grid steps amortize loop overhead. The 256-block
    # variants run the primary shape in a single grid step.
    GemmVariant(256, 256, 128, fuse_scales=True, acc_in_scratch=True),
    GemmVariant(256, 256, 256, fuse_scales=True, acc_in_scratch=True),
]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(variant: GemmVariant | None, m: int, k: int, n: int) -> str:
    fn, specs = model.entry(variant, m, k, n)
    return to_hlo_text(jax.jit(fn).lower(*specs))


def _fits(variant: GemmVariant, m: int, k: int, n: int) -> bool:
    try:
        variant.validate(m, k, n)
        return True
    except ValueError:
        return False


def build_catalog(out_dir: pathlib.Path, shapes=None, variants=None,
                  verbose: bool = True) -> dict:
    shapes = shapes or SHAPES
    variants = variants if variants is not None else VARIANTS
    out_dir.mkdir(parents=True, exist_ok=True)
    entries = []
    for (m, k, n) in shapes:
        # library reference path (the 'PyTorch reference' Table-1 row)
        name = f"ref_m{m}k{k}n{n}"
        text = lower_entry(None, m, k, n)
        (out_dir / f"{name}.hlo.txt").write_text(text)
        entries.append({
            "name": name, "kind": "reference", "m": m, "k": k, "n": n,
            "variant": None, "artifact": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        })
        if verbose:
            print(f"  wrote {name} ({len(text)} chars)", file=sys.stderr)
        for v in variants:
            if not _fits(v, m, k, n):
                continue
            name = f"{v.name}_m{m}k{k}n{n}"
            text = lower_entry(v, m, k, n)
            (out_dir / f"{name}.hlo.txt").write_text(text)
            entries.append({
                "name": name, "kind": "pallas", "m": m, "k": k, "n": n,
                "variant": dataclasses.asdict(v),
                "vmem_bytes": v.vmem_bytes(),
                "artifact": f"{name}.hlo.txt",
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            })
            if verbose:
                print(f"  wrote {name} ({len(text)} chars)", file=sys.stderr)
    catalog = {"version": 1, "entries": entries}
    (out_dir / "catalog.json").write_text(json.dumps(catalog, indent=2))
    return catalog


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts/model.hlo.txt",
                   help="sentinel artifact path; the catalog is written "
                        "to its directory")
    p.add_argument("--quick", action="store_true",
                   help="single shape + 3 variants (CI smoke)")
    args = p.parse_args()
    out_dir = pathlib.Path(args.out).parent
    shapes = SHAPES[:1] if args.quick else None
    variants = VARIANTS[:3] if args.quick else None
    catalog = build_catalog(out_dir, shapes=shapes, variants=variants)
    # The Makefile sentinel: model.hlo.txt is the default evolved variant
    # at the primary shape (also present in the catalog under its name).
    m, k, n = SHAPES[0]
    sentinel = lower_entry(GemmVariant(), m, k, n) \
        if _fits(GemmVariant(), m, k, n) else lower_entry(None, m, k, n)
    pathlib.Path(args.out).write_text(sentinel)
    print(f"catalog: {len(catalog['entries'])} artifacts in {out_dir}")


if __name__ == "__main__":
    main()
