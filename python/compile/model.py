"""Layer-2 JAX model: the end-to-end competition computation.

The full task graph: f32 operands -> per-row/col fp8 quantization ->
Layer-1 Pallas block-scaled GEMM -> (scales + bf16 cast if the kernel
variant did not fuse them) -> f32 boundary convert.

The graph is lowered once by ``aot.py`` to HLO text per kernel variant;
the rust coordinator (Layer 3) loads the artifacts via PJRT and times
them as its *real* evaluation backend. Entry parameters and results are
f32 so the rust ``xla`` crate only handles standard literals — the fp8
and bf16 segments live entirely inside the HLO module.

Python is never on the request path: this module is imported only by
``aot.py`` and the pytest suite.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.fp8_gemm import GemmVariant, fp8_gemm


def scaled_gemm(a: jax.Array, b: jax.Array,
                variant: GemmVariant = GemmVariant()) -> jax.Array:
    """Full task on f32 inputs, through the Pallas kernel.

    Returns f32 ``[M, N]`` (bf16 result widened at the boundary).
    """
    a_q, a_scale = ref.quantize_rowwise(a)
    b_q, b_scale = ref.quantize_colwise(b)
    out = fp8_gemm(a_q, b_q, a_scale, b_scale, variant)
    if not variant.fuse_scales:
        # unfused variants return the raw f32 accumulator; apply the
        # dequant scales and the bf16 cast here in the L2 graph.
        out = (out * a_scale * b_scale).astype(jnp.bfloat16)
    return out.astype(jnp.float32)


def scaled_gemm_reference(a: jax.Array, b: jax.Array) -> jax.Array:
    """The library path (no Pallas): the 'PyTorch reference' row of
    Table 1, compiled to its own artifact so the rust side can time the
    baseline through the identical runtime."""
    return ref.ref_gemm(a, b).astype(jnp.float32)


def entry(variant: GemmVariant | None, m: int, k: int, n: int):
    """Build the jittable entry function + example shapes for AOT.

    ``variant=None`` selects the library reference path.
    """
    a_spec = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b_spec = jax.ShapeDtypeStruct((k, n), jnp.float32)

    if variant is None:
        def fn(a, b):
            return (scaled_gemm_reference(a, b),)
    else:
        def fn(a, b):
            return (scaled_gemm(a, b, variant),)

    return fn, (a_spec, b_spec)
