"""Pure-jnp correctness oracle for the block-scaled FP8 GEMM.

This is the quantization + GEMM semantics of the competition task (the
"(provided) basic PyTorch implementation" of the paper's seed set),
written in plain jnp with no Pallas. Every kernel variant must agree
with this oracle (with a small tolerance: block-tiled accumulation
reassociates the k-sum).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: Largest magnitude representable in fp8-e4m3fn (OCP variant jax uses).
FP8_E4M3_MAX = 448.0


def quantize_rowwise(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric quantization of f32 ``[R, C]`` to fp8-e4m3.

    Returns ``(x_q, scale)`` with ``scale`` of shape ``[R, 1]`` such that
    ``deq(x_q) = x_q.astype(f32) * scale ~= x``.
    """
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / FP8_E4M3_MAX
    x_q = (x / scale).astype(jnp.float8_e4m3fn)
    return x_q, scale.astype(jnp.float32)


def quantize_colwise(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-column symmetric quantization of f32 ``[R, C]`` to fp8-e4m3.

    Returns ``(x_q, scale)`` with ``scale`` of shape ``[1, C]``.
    """
    absmax = jnp.max(jnp.abs(x), axis=0, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / FP8_E4M3_MAX
    x_q = (x / scale).astype(jnp.float8_e4m3fn)
    return x_q, scale.astype(jnp.float32)


def ref_gemm_quantized(a_q: jax.Array, b_q: jax.Array, a_scale: jax.Array,
                       b_scale: jax.Array) -> jax.Array:
    """Oracle on already-quantized inputs: fp8 -> f32 matmul -> scale ->
    bf16. Mirrors the kernel's dtype path exactly (fp8 compute, f32
    accumulate, bf16 out — the mixed-precision pattern of App. A.3)."""
    acc = jnp.dot(a_q.astype(jnp.float32), b_q.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return (acc * a_scale * b_scale).astype(jnp.bfloat16)


def ref_gemm(a: jax.Array, b: jax.Array) -> jax.Array:
    """End-to-end oracle on f32 inputs: quantize both operands, then
    :func:`ref_gemm_quantized`. This is the task semantics the
    competition's PyTorch reference implements."""
    a_q, a_scale = quantize_rowwise(a)
    b_q, b_scale = quantize_colwise(b)
    return ref_gemm_quantized(a_q, b_q, a_scale, b_scale)


def ref_gemm_exact(a: jax.Array, b: jax.Array) -> jax.Array:
    """Unquantized f32 GEMM — used to bound the quantization error of
    the task semantics themselves in tests."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)
