"""Layer-1 Pallas kernel: block-tiled FP8 GEMM with fused block scaling.

This is the competition kernel of the AMD Developer Challenge 2025 as
described in the paper (App. A.3), adapted from MI300/HIP to TPU/Pallas
idioms (see DESIGN.md §Hardware-Adaptation):

  * MI300 LDS ping-pong tiles  ->  Pallas BlockSpec VMEM blocks; the
    HBM<->VMEM pipeline is expressed by the (m, n, k) grid + index maps.
  * MFMA 32x32x16 fp8 matrix core  ->  MXU matmul via ``jnp.dot`` with an
    f32 ``preferred_element_type`` on fp8-cast inputs.
  * fp8-e4m3 inputs, f32 accumulate, bf16 out  ->  identical dtype path.
  * per-matrix scale application  ->  fused (or unfused) scaling of the
    f32 accumulator before the bf16 cast.

The kernel is *parameterized* — the genome axes the rust coordinator
evolves (tile sizes, fused scaling, accumulator placement, grid walk)
select a variant here; ``aot.py`` compiles a catalog of variants to HLO
text that the rust PJRT runtime loads and times.

Pallas runs with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the kernel is lowered to plain HLO (the grid
becomes an XLA while-loop). Numerics are identical to the TPU path.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


@dataclasses.dataclass(frozen=True)
class GemmVariant:
    """A point in the (projected) kernel genome space.

    Mirrors the rust ``genome::KernelGenome`` fields that survive the
    projection onto what Pallas can express on this testbed. The rust
    side carries the full genome (LDS padding, waves/block, writeback
    strategy, ...) for the MI300 simulator backend.
    """

    block_m: int = 128
    block_n: int = 128
    block_k: int = 64
    #: apply the per-row/per-col scales inside the kernel epilogue
    #: (fused) or as a separate jnp pass in the L2 graph (unfused).
    fuse_scales: bool = True
    #: carry the f32 accumulator in a VMEM scratch buffer across k-steps
    #: (the LDS-resident accumulation of the paper's evolved kernel) vs.
    #: accumulating into the output ref (the naive-translation shape).
    acc_in_scratch: bool = True
    #: k-innermost grid walk (finish an (m, n) tile's reduction before
    #: moving on — accumulator locality) vs k-outermost (the naive walk
    #: that revisits every output tile per k-step).
    k_innermost: bool = True

    @property
    def name(self) -> str:
        return (
            f"g{self.block_m}x{self.block_n}x{self.block_k}"
            f"_{'fs' if self.fuse_scales else 'us'}"
            f"_{'sc' if self.acc_in_scratch else 'oa'}"
            f"_{'ki' if self.k_innermost else 'ko'}"
        )

    def validate(self, m: int, k: int, n: int) -> None:
        for dim, blk, label in (
            (m, self.block_m, "m"),
            (n, self.block_n, "n"),
            (k, self.block_k, "k"),
        ):
            if dim % blk != 0:
                raise ValueError(
                    f"{label}={dim} not divisible by block_{label}={blk} "
                    f"for variant {self.name}"
                )
            if blk < 8 or blk & (blk - 1):
                raise ValueError(f"blocks must be pow2 >= 8, got {blk}")
        if self.acc_in_scratch and not self.k_innermost:
            raise ValueError(
                "scratch accumulator requires the k-innermost walk "
                "(a k-outermost walk clobbers the scratch between visits)"
            )

    def vmem_bytes(self) -> int:
        """Static VMEM footprint of one grid step (A, B blocks fp8 +
        scale slivers f32 + out block + f32 scratch accumulator).

        Used by the AOT catalog metadata and checked against the 16 MiB
        budget in DESIGN.md §Perf.
        """
        a = self.block_m * self.block_k  # fp8: 1 byte
        b = self.block_k * self.block_n
        scales = 4 * (self.block_m + self.block_n)
        out_elt = 2 if self.fuse_scales else 4
        out = self.block_m * self.block_n * out_elt
        acc = self.block_m * self.block_n * 4 if self.acc_in_scratch else 0
        return a + b + scales + out + acc


def _kernel_scratch(nk: int, fuse_scales: bool,
                    a_ref, b_ref, asc_ref, bsc_ref, o_ref, acc_ref):
    """Grid body with an f32 VMEM scratch accumulator (the paper's
    evolved-kernel structure: private accumulator, single epilogue)."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k_step == nk - 1)
    def _epilogue():
        acc = acc_ref[...]
        if fuse_scales:
            acc = acc * asc_ref[...] * bsc_ref[...]
        o_ref[...] = acc.astype(o_ref.dtype)


def _kernel_out_acc(nk: int, fuse_scales: bool, k_axis: int,
                    a_ref, b_ref, asc_ref, bsc_ref, o_ref):
    """Grid body accumulating into the output ref directly — the
    naive-translation structure (no private accumulator, output tile
    re-read/re-written every k step)."""
    k_step = pl.program_id(k_axis)

    @pl.when(k_step == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    @pl.when(k_step == nk - 1)
    def _epilogue():
        if fuse_scales:
            o_ref[...] = o_ref[...] * asc_ref[...] * bsc_ref[...]


def fp8_gemm(a_q: jax.Array, b_q: jax.Array, a_scale: jax.Array,
             b_scale: jax.Array, variant: GemmVariant = GemmVariant()):
    """Block-scaled GEMM ``C = (deq(a_q) @ deq(b_q))`` as a Pallas call.

    Args:
      a_q:      fp8-e4m3 ``[M, K]`` quantized A.
      b_q:      fp8-e4m3 ``[K, N]`` quantized B.
      a_scale:  f32 ``[M, 1]`` per-row dequant scale of A.
      b_scale:  f32 ``[1, N]`` per-col dequant scale of B.
      variant:  kernel genome projection to compile.

    Returns:
      bf16 ``[M, N]`` (fused-scale variants) or f32 ``[M, N]`` raw
      accumulator (unfused variants — the L2 graph applies scales and
      the bf16 cast).
    """
    m, k = a_q.shape
    k2, n = b_q.shape
    assert k == k2, (a_q.shape, b_q.shape)
    variant.validate(m, k, n)
    nm, nn, nk = m // variant.block_m, n // variant.block_n, k // variant.block_k

    if variant.k_innermost:
        grid = (nm, nn, nk)
        a_map = lambda i, j, s: (i, s)
        b_map = lambda i, j, s: (s, j)
        o_map = lambda i, j, s: (i, j)
        sa_map = lambda i, j, s: (i, 0)
        sb_map = lambda i, j, s: (0, j)
        k_axis = 2
    else:
        grid = (nk, nm, nn)
        a_map = lambda s, i, j: (i, s)
        b_map = lambda s, i, j: (s, j)
        o_map = lambda s, i, j: (i, j)
        sa_map = lambda s, i, j: (i, 0)
        sb_map = lambda s, i, j: (0, j)
        k_axis = 0

    in_specs = [
        pl.BlockSpec((variant.block_m, variant.block_k), a_map),
        pl.BlockSpec((variant.block_k, variant.block_n), b_map),
        pl.BlockSpec((variant.block_m, 1), sa_map),
        pl.BlockSpec((1, variant.block_n), sb_map),
    ]
    out_spec = pl.BlockSpec((variant.block_m, variant.block_n), o_map)

    if variant.acc_in_scratch:
        out_dtype = jnp.bfloat16 if variant.fuse_scales else jnp.float32
        return pl.pallas_call(
            functools.partial(_kernel_scratch, nk, variant.fuse_scales),
            grid=grid,
            in_specs=in_specs,
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            scratch_shapes=[
                pltpu.VMEM((variant.block_m, variant.block_n), jnp.float32)
            ],
            interpret=True,
        )(a_q, b_q, a_scale, b_scale)

    out = pl.pallas_call(
        functools.partial(_kernel_out_acc, nk, variant.fuse_scales, k_axis),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a_q, b_q, a_scale, b_scale)
    if variant.fuse_scales:
        return out.astype(jnp.bfloat16)
    return out
