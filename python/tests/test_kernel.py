"""Kernel-vs-oracle correctness: the CORE build-time signal.

Every Pallas variant must agree with the pure-jnp oracle on the same
quantized inputs. Hypothesis sweeps shapes x variant axes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fp8_gemm import GemmVariant, fp8_gemm

jax.config.update("jax_enable_x64", False)


def rand(shape, seed, scale=1.0):
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


def run_variant(v: GemmVariant, m: int, k: int, n: int, seed: int = 0,
                scale: float = 1.0):
    a = rand((m, k), seed, scale)
    b = rand((k, n), seed + 1, scale)
    a_q, a_s = ref.quantize_rowwise(a)
    b_q, b_s = ref.quantize_colwise(b)
    got = fp8_gemm(a_q, b_q, a_s, b_s, v)
    if not v.fuse_scales:
        got = (got * a_s * b_s).astype(jnp.bfloat16)
    want = ref.ref_gemm_quantized(a_q, b_q, a_s, b_s)
    return np.asarray(got, np.float32), np.asarray(want, np.float32)


def assert_matches(got, want, k):
    # block-tiled accumulation reassociates the k-sum; bf16 output has
    # ~3 decimal digits. Tolerance scales with sqrt(k).
    tol = 2e-2 * np.sqrt(k / 64.0) * np.maximum(np.abs(want).max(), 1.0)
    np.testing.assert_allclose(got, want, atol=float(tol), rtol=2e-2)


DEFAULT = GemmVariant()


class TestDefaultVariant:
    def test_square(self):
        got, want = run_variant(DEFAULT, 128, 128, 128)
        assert_matches(got, want, 128)

    def test_rectangular(self):
        got, want = run_variant(DEFAULT, 256, 64, 128)
        assert_matches(got, want, 64)

    def test_large_scale_inputs(self):
        got, want = run_variant(DEFAULT, 128, 128, 128, scale=100.0)
        assert_matches(got, want, 128)

    def test_small_scale_inputs(self):
        got, want = run_variant(DEFAULT, 128, 128, 128, scale=1e-3)
        assert_matches(got, want, 128)

    def test_output_dtype_is_bf16(self):
        a = rand((128, 64), 0)
        b = rand((64, 128), 1)
        a_q, a_s = ref.quantize_rowwise(a)
        b_q, b_s = ref.quantize_colwise(b)
        out = fp8_gemm(a_q, b_q, a_s, b_s, GemmVariant(64, 64, 64))
        assert out.dtype == jnp.bfloat16


VARIANT_MATRIX = [
    GemmVariant(32, 32, 32, fuse_scales=False, acc_in_scratch=False,
                k_innermost=False),
    GemmVariant(32, 32, 32, fuse_scales=True, acc_in_scratch=False,
                k_innermost=True),
    GemmVariant(64, 32, 32),
    GemmVariant(32, 64, 32),
    GemmVariant(32, 32, 64),
    GemmVariant(64, 64, 64, fuse_scales=False),
    GemmVariant(64, 64, 64, acc_in_scratch=False),
    GemmVariant(128, 64, 32),
]


@pytest.mark.parametrize("v", VARIANT_MATRIX, ids=lambda v: v.name)
def test_variant_matrix(v):
    got, want = run_variant(v, 128, 128, 128, seed=7)
    assert_matches(got, want, 128)


class TestValidation:
    def test_indivisible_m_rejected(self):
        with pytest.raises(ValueError, match="not divisible"):
            GemmVariant(64, 32, 32).validate(100, 64, 64)

    def test_non_pow2_block_rejected(self):
        with pytest.raises(ValueError, match="pow2"):
            GemmVariant(48, 32, 32).validate(96, 64, 64)

    def test_tiny_block_rejected(self):
        with pytest.raises(ValueError, match="pow2"):
            GemmVariant(4, 32, 32).validate(64, 64, 64)

    def test_scratch_requires_k_innermost(self):
        with pytest.raises(ValueError, match="k-innermost"):
            GemmVariant(32, 32, 32, acc_in_scratch=True,
                        k_innermost=False).validate(64, 64, 64)

    def test_vmem_bytes_monotone_in_blocks(self):
        small = GemmVariant(32, 32, 32).vmem_bytes()
        big = GemmVariant(128, 128, 64).vmem_bytes()
        assert big > small

    def test_vmem_under_budget(self):
        # DESIGN.md §Perf: every catalog variant fits the 16 MiB budget.
        from compile.aot import VARIANTS
        for v in VARIANTS:
            assert v.vmem_bytes() <= 16 * 2**20, v.name


# ---------------------------------------------------------------- hypothesis

pow2 = st.sampled_from([32, 64, 128])
mult = st.integers(min_value=1, max_value=3)


@settings(max_examples=12, deadline=None)
@given(bm=st.sampled_from([32, 64]), bn=st.sampled_from([32, 64]),
       bk=st.sampled_from([32, 64]), mm=mult, nn=mult, kk=mult,
       fuse=st.booleans(), scratch=st.booleans(),
       seed=st.integers(0, 2**16))
def test_hypothesis_shape_sweep(bm, bn, bk, mm, nn, kk, fuse, scratch, seed):
    v = GemmVariant(bm, bn, bk, fuse_scales=fuse, acc_in_scratch=scratch,
                    k_innermost=True)
    m, n, k = bm * mm, bn * nn, bk * kk
    got, want = run_variant(v, m, k, n, seed=seed)
    assert_matches(got, want, k)


@settings(max_examples=8, deadline=None)
@given(scale=st.floats(min_value=1e-4, max_value=1e4),
       seed=st.integers(0, 2**16))
def test_hypothesis_dynamic_range(scale, seed):
    """Scale sweeps exercise the per-row/col quantization path: the
    dequantized kernel output must track the oracle at any input range."""
    got, want = run_variant(DEFAULT, 128, 64, 128, seed=seed, scale=scale)
    assert_matches(got, want, 64)


class TestQuantization:
    def test_rowwise_roundtrip(self):
        x = rand((64, 32), 3, scale=10.0)
        x_q, s = ref.quantize_rowwise(x)
        deq = np.asarray(x_q, np.float32) * np.asarray(s)
        np.testing.assert_allclose(deq, np.asarray(x), rtol=0.15, atol=0.2)

    def test_colwise_roundtrip(self):
        x = rand((32, 64), 4, scale=0.1)
        x_q, s = ref.quantize_colwise(x)
        deq = np.asarray(x_q, np.float32) * np.asarray(s)
        np.testing.assert_allclose(deq, np.asarray(x), rtol=0.15, atol=0.01)

    def test_scale_shapes(self):
        x = rand((16, 8), 5)
        _, sr = ref.quantize_rowwise(x)
        _, sc = ref.quantize_colwise(x)
        assert sr.shape == (16, 1) and sc.shape == (1, 8)

    def test_quantized_rows_saturate_fp8_range(self):
        x = rand((8, 128), 6, scale=50.0)
        x_q, _ = ref.quantize_rowwise(x)
        per_row_max = np.abs(np.asarray(x_q, np.float32)).max(axis=1)
        assert (per_row_max > 0.9 * ref.FP8_E4M3_MAX).all()

    def test_task_semantics_close_to_exact(self):
        a, b = rand((64, 64), 7), rand((64, 64), 8)
        approx = np.asarray(ref.ref_gemm(a, b), np.float32)
        exact = np.asarray(ref.ref_gemm_exact(a, b))
        # fp8 quantization error on a k=64 dot: a few percent.
        err = np.abs(approx - exact).max() / (np.abs(exact).max() + 1e-9)
        assert err < 0.12, err


class TestEdgeCases:
    def test_single_block_shape(self):
        # degenerate grid: exactly one block in every dimension
        v = GemmVariant(32, 32, 32)
        got, want = run_variant(v, 32, 32, 32, seed=11)
        assert_matches(got, want, 32)

    def test_deep_k_reduction(self):
        # many k-steps stress the accumulator carry
        v = GemmVariant(32, 32, 32)
        got, want = run_variant(v, 32, 512, 32, seed=12)
        assert_matches(got, want, 512)

    def test_wide_aspect_ratio(self):
        v = GemmVariant(32, 64, 32)
        got, want = run_variant(v, 32, 64, 512, seed=13)
        assert_matches(got, want, 64)

    def test_zero_inputs(self):
        a_q = jnp.zeros((64, 64), jnp.float8_e4m3fn)
        b_q = jnp.zeros((64, 64), jnp.float8_e4m3fn)
        s1 = jnp.ones((64, 1), jnp.float32)
        s2 = jnp.ones((1, 64), jnp.float32)
        out = fp8_gemm(a_q, b_q, s1, s2, GemmVariant(32, 32, 32))
        assert not np.asarray(out, np.float32).any()

    def test_identity_like(self):
        # A = diag-ish pattern quantizes exactly (powers of two)
        a = jnp.eye(64, dtype=jnp.float32) * 2.0
        b = jax.random.normal(jax.random.PRNGKey(5), (64, 64), jnp.float32)
        a_q, a_s = ref.quantize_rowwise(a)
        b_q, b_s = ref.quantize_colwise(b)
        got = fp8_gemm(a_q, b_q, a_s, b_s, GemmVariant(32, 32, 32))
        want = ref.ref_gemm_quantized(a_q, b_q, a_s, b_s)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=2e-2, atol=1e-2)

    def test_perf_pass_variants_correct(self):
        # the §Perf single-grid-step variants added to the catalog
        for v in (GemmVariant(256, 256, 128), GemmVariant(256, 256, 256)):
            got, want = run_variant(v, 256, 256, 256, seed=14)
            assert_matches(got, want, 256)

    def test_vmem_of_perf_variants_under_budget(self):
        assert GemmVariant(256, 256, 256).vmem_bytes() <= 16 * 2**20
