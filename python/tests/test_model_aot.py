"""L2 model graph + AOT catalog tests: shapes, lowering, catalog format."""

from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref
from compile.kernels.fp8_gemm import GemmVariant


class TestModel:
    def test_scaled_gemm_matches_reference_path(self):
        key = jax.random.PRNGKey(0)
        a = jax.random.normal(key, (128, 64), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (64, 128), jnp.float32)
        got = np.asarray(model.scaled_gemm(a, b, GemmVariant(64, 64, 64)))
        want = np.asarray(model.scaled_gemm_reference(a, b))
        np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2
                                   * max(1.0, float(np.abs(want).max())))

    def test_unfused_variant_matches_fused(self):
        a = jax.random.normal(jax.random.PRNGKey(2), (64, 64), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(3), (64, 64), jnp.float32)
        fused = np.asarray(model.scaled_gemm(a, b, GemmVariant(32, 32, 32)))
        unfused = np.asarray(model.scaled_gemm(
            a, b, GemmVariant(32, 32, 32, fuse_scales=False)))
        np.testing.assert_allclose(fused, unfused, rtol=1e-2, atol=1e-2
                                   * max(1.0, float(np.abs(fused).max())))

    def test_output_is_f32_at_boundary(self):
        a = jnp.ones((32, 32), jnp.float32)
        b = jnp.ones((32, 32), jnp.float32)
        out = model.scaled_gemm(a, b, GemmVariant(32, 32, 32))
        assert out.dtype == jnp.float32
        assert model.scaled_gemm_reference(a, b).dtype == jnp.float32

    def test_entry_reference_and_variant(self):
        fn, specs = model.entry(None, 32, 32, 32)
        assert specs[0].shape == (32, 32)
        out = jax.eval_shape(fn, *specs)
        assert out[0].shape == (32, 32) and out[0].dtype == jnp.float32
        fn2, _ = model.entry(GemmVariant(32, 32, 32), 32, 32, 32)
        out2 = jax.eval_shape(fn2, *specs)
        assert out2[0].shape == (32, 32)


class TestAot:
    def test_lower_entry_produces_hlo_text(self):
        text = aot.lower_entry(GemmVariant(32, 32, 32), 64, 32, 64)
        assert text.startswith("HloModule")
        assert "f8e4m3fn" in text  # the fp8 segment is inside the module
        assert "bf16" in text      # ... and the bf16 epilogue

    def test_lower_reference_entry(self):
        text = aot.lower_entry(None, 64, 64, 64)
        assert text.startswith("HloModule")
        assert "f32[64,64]" in text

    def test_catalog_build_quick(self, tmp_path):
        cat = aot.build_catalog(tmp_path, shapes=[(64, 64, 64)],
                                variants=[GemmVariant(32, 32, 32)],
                                verbose=False)
        assert len(cat["entries"]) == 2  # reference + 1 pallas variant
        names = {e["name"] for e in cat["entries"]}
        assert "ref_m64k64n64" in names
        data = json.loads((tmp_path / "catalog.json").read_text())
        assert data["version"] == 1
        for e in data["entries"]:
            p = tmp_path / e["artifact"]
            assert p.exists() and p.read_text().startswith("HloModule")

    def test_catalog_skips_nonfitting_variants(self, tmp_path):
        cat = aot.build_catalog(tmp_path, shapes=[(64, 64, 64)],
                                variants=[GemmVariant(128, 128, 128)],
                                verbose=False)
        kinds = [e["kind"] for e in cat["entries"]]
        assert kinds == ["reference"]  # 128-block doesn't fit 64^3

    def test_default_variant_fits_all_default_shapes(self):
        for (m, k, n) in aot.SHAPES:
            GemmVariant().validate(m, k, n)

    def test_all_catalog_variants_valid_somewhere(self):
        for v in aot.VARIANTS:
            assert any(aot._fits(v, m, k, n) for (m, k, n) in aot.SHAPES), \
                f"{v.name} fits no catalog shape"

    def test_catalog_names_unique(self, tmp_path):
        cat = aot.build_catalog(tmp_path, shapes=[(64, 64, 64), (128, 64, 64)],
                                variants=[GemmVariant(32, 32, 32),
                                          GemmVariant(64, 32, 32)],
                                verbose=False)
        names = [e["name"] for e in cat["entries"]]
        assert len(names) == len(set(names))
