"""Make `pytest python/tests/` work from the repo root: the test suite
imports the build-time `compile` package relative to python/."""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent.resolve()))
