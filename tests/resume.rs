//! Resume-equivalence suite (DESIGN.md §9; the acceptance bar of the
//! run-store subsystem): an interrupted-then-resumed run is
//! **bit-identical** — full ledger, transcripts, convergence curve,
//! wall clock, cache stats, scheduler stats — to a run that was never
//! interrupted, for every registered workload, under both the lockstep
//! and steady-state-pipeline schedulers, at one and several lanes.
//!
//! Interruption is simulated with the `halt_after` knob: the scheduler
//! aborts mid-campaign *without* a final checkpoint, exactly like a
//! crash — resume has only the last periodic checkpoint plus the
//! journal tail to work from (and must discard the tail past the
//! checkpoint).

use std::path::Path;

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::eval::EvalBackend;
use gpu_kernel_scientist::report;
use gpu_kernel_scientist::scientist::{RunOutcome, ScientistRun};
use gpu_kernel_scientist::test_support::scratch_dir;
use gpu_kernel_scientist::workload::{registry, Workload};
use gpu_kernel_scientist::{store, workloads};

fn store_config(
    workload: &str,
    seed: u64,
    budget: u64,
    lanes: u32,
    pipeline: bool,
    dir: &Path,
) -> RunConfig {
    let mut cfg = RunConfig::default()
        .with_workload(workload)
        .with_seed(seed)
        .with_budget(budget)
        .with_parallelism(lanes)
        .with_pipeline(pipeline);
    cfg.store_dir = Some(dir.display().to_string());
    cfg
}

/// The full bit-identity assertion: ledger, transcripts, curve,
/// platform accounting, cache stats, scheduler stats.
fn assert_bit_identical<B: EvalBackend>(
    label: &str,
    full: &ScientistRun<B>,
    full_out: &RunOutcome,
    resumed: &ScientistRun<B>,
    resumed_out: &RunOutcome,
) {
    assert_eq!(
        full.population.members(),
        resumed.population.members(),
        "{label}: full ledger (genomes, lineage, reports, outcomes)"
    );
    let render = |run: &ScientistRun<B>| -> Vec<String> {
        run.logs.iter().map(report::render_iteration).collect()
    };
    assert_eq!(render(full), render(resumed), "{label}: iteration transcripts");
    assert_eq!(
        full_out.curve.points, resumed_out.curve.points,
        "{label}: convergence curve"
    );
    assert_eq!(full_out.best_id, resumed_out.best_id, "{label}: best id");
    assert_eq!(
        full_out.best_geomean_us, resumed_out.best_geomean_us,
        "{label}: best geomean (bitwise)"
    );
    assert_eq!(
        full_out.leaderboard_us, resumed_out.leaderboard_us,
        "{label}: leaderboard score (bitwise)"
    );
    assert_eq!(
        full_out.submissions, resumed_out.submissions,
        "{label}: submissions"
    );
    assert_eq!(
        full_out.wall_clock_s, resumed_out.wall_clock_s,
        "{label}: simulated wall clock (bitwise)"
    );
    assert_eq!(
        full.platform.cache_stats(),
        resumed.platform.cache_stats(),
        "{label}: cache stats"
    );
    assert_eq!(
        full_out.pipeline, resumed_out.pipeline,
        "{label}: scheduler stats (occupancy, depth, planning rounds)"
    );
}

/// Run the (workload, scheduler, lanes) configuration twice — once
/// uninterrupted, once crashed at `halt_after` submissions and then
/// resumed — and assert bit-identity.
fn resume_matches_uninterrupted(
    workload: &str,
    seed: u64,
    budget: u64,
    lanes: u32,
    pipeline: bool,
    halt_after: u64,
    checkpoint_every: u64,
) {
    let label = format!(
        "{workload} {} lanes={lanes} halt={halt_after} every={checkpoint_every}",
        if pipeline { "pipeline" } else { "lockstep" }
    );
    let full_dir = scratch_dir("full");
    let crash_dir = scratch_dir("crash");

    let mut full_cfg = store_config(workload, seed, budget, lanes, pipeline, &full_dir);
    full_cfg.checkpoint_every = checkpoint_every;
    let mut full = ScientistRun::new(full_cfg).expect("uninterrupted setup");
    let full_out = full.run_to_completion().expect("uninterrupted run");
    assert!(!full.halted());

    let mut crash_cfg = store_config(workload, seed, budget, lanes, pipeline, &crash_dir);
    crash_cfg.checkpoint_every = checkpoint_every;
    crash_cfg.halt_after = Some(halt_after);
    let mut crashed = ScientistRun::new(crash_cfg).expect("crashing setup");
    let _ = crashed.run_to_completion().expect("halted run");
    assert!(crashed.halted(), "{label}: halt_after should trip");
    let crashed_subs = crashed.platform.submissions();
    assert!(
        crashed_subs < budget,
        "{label}: the crash must interrupt mid-campaign"
    );
    drop(crashed); // the process is gone; only the store survives

    let mut resumed = ScientistRun::resume(&crash_dir).expect("resume");
    assert!(
        resumed.platform.submissions() <= crashed_subs,
        "{label}: resume starts from the last checkpoint, not past the crash"
    );
    let resumed_out = resumed.run_to_completion().expect("resumed run");
    assert!(!resumed.halted(), "{label}: halt knob is not persisted");
    assert_bit_identical(&label, &full, &full_out, &resumed, &resumed_out);
}

#[test]
fn lockstep_resume_is_bit_identical_for_every_workload() {
    for w in registry() {
        resume_matches_uninterrupted(w.name(), 7, 24, 1, false, 12, 1);
    }
}

#[test]
fn pipeline_resume_is_bit_identical_for_every_workload() {
    for w in registry() {
        resume_matches_uninterrupted(w.name(), 5, 24, 1, true, 12, 1);
    }
}

#[test]
fn multi_lane_lockstep_resume_is_bit_identical() {
    // lockstep at several lanes: ephemeral per-batch lane forks, so the
    // parent backend snapshot alone must carry the noise streams
    resume_matches_uninterrupted("fp8-gemm", 11, 26, 3, false, 14, 1);
}

#[test]
fn multi_lane_pipeline_resume_is_bit_identical() {
    // pipeline at several lanes: persistent stream workers — resume
    // re-forks the lane backends from the pre-spawn state and replays
    // each lane's committed FIFO prefix, and checkpoints taken with
    // work in flight unwind it exactly
    resume_matches_uninterrupted("fp8-gemm", 3, 26, 3, true, 14, 1);
    resume_matches_uninterrupted("row-softmax", 9, 24, 2, true, 13, 1);
}

#[test]
fn deep_pipeline_resume_with_stale_checkpoint() {
    // inflight_per_lane > 1 plus a checkpoint cadence > 1: the crash
    // lands several completions past the last checkpoint, so resume
    // must discard the journal tail and re-derive it live
    let full_dir = scratch_dir("full");
    let crash_dir = scratch_dir("crash");
    let mk = |dir: &Path| {
        let mut cfg = store_config("bf16-gemm", 13, 28, 2, true, dir);
        cfg.inflight_per_lane = 2;
        cfg.checkpoint_every = 3;
        cfg
    };
    let mut full = ScientistRun::new(mk(&full_dir)).unwrap();
    let full_out = full.run_to_completion().unwrap();
    let mut crash_cfg = mk(&crash_dir);
    crash_cfg.halt_after = Some(15);
    let mut crashed = ScientistRun::new(crash_cfg).unwrap();
    let _ = crashed.run_to_completion().unwrap();
    assert!(crashed.halted());
    drop(crashed);
    let mut resumed = ScientistRun::resume(&crash_dir).unwrap();
    let resumed_out = resumed.run_to_completion().unwrap();
    assert_bit_identical("deep pipeline", &full, &full_out, &resumed, &resumed_out);
}

#[test]
fn resume_with_the_eval_cache_disabled_is_bit_identical() {
    // cache off: counted stats stay (0, 0) and a mid-flight checkpoint
    // must not try to subtract uncounted misses — the rolled-back
    // stats mirror submit_stream's counting rule exactly
    let full_dir = scratch_dir("full");
    let crash_dir = scratch_dir("crash");
    let mk = |dir: &Path| {
        let mut cfg = store_config("fp8-gemm", 27, 24, 2, true, dir);
        cfg.eval_cache = false;
        cfg
    };
    let mut full = ScientistRun::new(mk(&full_dir)).unwrap();
    let full_out = full.run_to_completion().unwrap();
    let mut crash_cfg = mk(&crash_dir);
    crash_cfg.halt_after = Some(13);
    let mut crashed = ScientistRun::new(crash_cfg).unwrap();
    let _ = crashed.run_to_completion().unwrap();
    assert!(crashed.halted());
    drop(crashed);
    let mut resumed = ScientistRun::resume(&crash_dir).unwrap();
    let resumed_out = resumed.run_to_completion().unwrap();
    assert_eq!(resumed.platform.cache_stats(), (0, 0));
    assert_bit_identical("cache off", &full, &full_out, &resumed, &resumed_out);
}

#[test]
fn failed_resume_leaves_the_journal_intact() {
    // corrupt the checkpoint so resume fails validation: the journal
    // tail must NOT be truncated (replay still renders full history)
    let dir = scratch_dir("preserve");
    let mut cfg = store_config("fp8-gemm", 33, 20, 1, false, &dir);
    cfg.checkpoint_every = 4; // leave journal entries past the checkpoint
    cfg.halt_after = Some(11);
    let mut crashed = ScientistRun::new(cfg).unwrap();
    let _ = crashed.run_to_completion().unwrap();
    assert!(crashed.halted());
    drop(crashed);
    let journal_before =
        std::fs::read_to_string(dir.join(store::JOURNAL_FILE)).unwrap();
    // sabotage: claim a different lane count than the run used
    let cp_path = dir.join("checkpoint.json");
    let cp = std::fs::read_to_string(&cp_path).unwrap();
    let cp = cp.replace("\"lane_busy_until\":[", "\"lane_busy_until\":[0,");
    std::fs::write(&cp_path, cp).unwrap();
    assert!(ScientistRun::resume(&dir).is_err());
    let journal_after =
        std::fs::read_to_string(dir.join(store::JOURNAL_FILE)).unwrap();
    assert_eq!(
        journal_before, journal_after,
        "a failed resume must not destroy the post-checkpoint history"
    );
}

#[test]
fn deep_inline_pipeline_resume_rewinds_the_parent_noise_stream() {
    // lanes = 1 with inflight_per_lane = 2: stream dispatches evaluate
    // *inline* on the parent backend at submit time, so a checkpoint
    // with work in flight must rewind the parent to the oldest
    // dispatch's recorded pre-state — the resumed re-dispatch then
    // redraws the exact same noise
    let full_dir = scratch_dir("full");
    let crash_dir = scratch_dir("crash");
    let mk = |dir: &Path| {
        let mut cfg = store_config("fp8-gemm", 19, 24, 1, true, dir);
        cfg.inflight_per_lane = 2;
        cfg
    };
    let mut full = ScientistRun::new(mk(&full_dir)).unwrap();
    let full_out = full.run_to_completion().unwrap();
    let mut crash_cfg = mk(&crash_dir);
    crash_cfg.halt_after = Some(13);
    let mut crashed = ScientistRun::new(crash_cfg).unwrap();
    let _ = crashed.run_to_completion().unwrap();
    assert!(crashed.halted());
    drop(crashed);
    let mut resumed = ScientistRun::resume(&crash_dir).unwrap();
    let resumed_out = resumed.run_to_completion().unwrap();
    assert_bit_identical("deep inline", &full, &full_out, &resumed, &resumed_out);
}

#[test]
fn resume_discards_a_torn_journal_tail() {
    // simulate a crash mid-append: garbage past the last checkpoint
    // must be truncated away, and the resumed run still matches the
    // uninterrupted one bit for bit
    let full_dir = scratch_dir("full");
    let crash_dir = scratch_dir("crash");
    let mut full_cfg = store_config("fp8-gemm", 17, 22, 1, false, &full_dir);
    full_cfg.checkpoint_every = 2;
    let mut full = ScientistRun::new(full_cfg).unwrap();
    let full_out = full.run_to_completion().unwrap();

    let mut crash_cfg = store_config("fp8-gemm", 17, 22, 1, false, &crash_dir);
    crash_cfg.checkpoint_every = 2;
    crash_cfg.halt_after = Some(11);
    let mut crashed = ScientistRun::new(crash_cfg).unwrap();
    let _ = crashed.run_to_completion().unwrap();
    assert!(crashed.halted());
    drop(crashed);
    // torn half-line at the journal's end
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .append(true)
        .open(crash_dir.join(store::JOURNAL_FILE))
        .unwrap();
    f.write_all(b"{\"t\":\"exp\",\"ind\":{\"trunc").unwrap();
    drop(f);

    let mut resumed = ScientistRun::resume(&crash_dir).unwrap();
    let resumed_out = resumed.run_to_completion().unwrap();
    assert_bit_identical("torn tail", &full, &full_out, &resumed, &resumed_out);
}

#[test]
fn mid_rung_crash_resume_is_bit_identical_with_screening() {
    // The screen tier's partial rung is scheduler state: a crash with
    // candidates awaiting a promotion decision must checkpoint them
    // (store::Checkpoint::screen_pending), and the resumed run —
    // re-scoring them with the pure cost model — must match the
    // uninterrupted one bit for bit, screened/promoted counters
    // included. Several halt points so at least one lands mid-rung.
    let mk = |dir: &Path| {
        let mut cfg = store_config("fp8-gemm", 41, 26, 2, true, dir).with_screen(4, 0.5);
        cfg.checkpoint_every = 1;
        cfg
    };
    let full_dir = scratch_dir("screen-full");
    let mut full = ScientistRun::new(mk(&full_dir)).unwrap();
    let full_out = full.run_to_completion().unwrap();
    assert!(full_out.pipeline.screened > 0, "screening must engage");
    assert_eq!(
        full_out.pipeline.screened,
        full_out.pipeline.screen_promoted + full_out.pipeline.screen_rejected,
        "every screened candidate is decided by the end of the run"
    );
    let mut any_mid_rung = false;
    for halt_after in [8u64, 10, 12, 14] {
        let crash_dir = scratch_dir("screen-crash");
        let mut crash_cfg = mk(&crash_dir);
        crash_cfg.halt_after = Some(halt_after);
        let mut crashed = ScientistRun::new(crash_cfg).unwrap();
        let _ = crashed.run_to_completion().unwrap();
        assert!(crashed.halted(), "halt={halt_after}");
        drop(crashed);
        let cp = store::Checkpoint::load(&crash_dir).unwrap();
        any_mid_rung |= !cp.screen_pending.is_empty();
        let mut resumed = ScientistRun::resume(&crash_dir).unwrap();
        let resumed_out = resumed.run_to_completion().unwrap();
        assert_bit_identical(
            &format!("screened halt={halt_after}"),
            &full,
            &full_out,
            &resumed,
            &resumed_out,
        );
    }
    assert!(
        any_mid_rung,
        "no halt point caught candidates in the screen rung — the mid-rung \
         path went untested; retune halt_after/rung"
    );
}

#[test]
fn store_instrumentation_never_perturbs_the_trajectory() {
    // a run with a store attached is bit-identical to one without
    use gpu_kernel_scientist::test_support::trajectory;
    for (pipeline, lanes) in [(false, 1), (true, 2)] {
        let dir = scratch_dir("instr");
        let base = RunConfig::default()
            .with_workload("row-softmax")
            .with_seed(21)
            .with_budget(20)
            .with_parallelism(lanes)
            .with_pipeline(pipeline);
        let mut plain = ScientistRun::new(base.clone()).unwrap();
        let plain_out = plain.run_to_completion().unwrap();
        let mut stored_cfg = base;
        stored_cfg.store_dir = Some(dir.display().to_string());
        let mut stored = ScientistRun::new(stored_cfg).unwrap();
        let stored_out = stored.run_to_completion().unwrap();
        assert_eq!(trajectory(&plain), trajectory(&stored));
        assert_eq!(plain_out.best_geomean_us, stored_out.best_geomean_us);
        assert_eq!(plain_out.wall_clock_s, stored_out.wall_clock_s);
        assert_eq!(plain.platform.cache_stats(), stored.platform.cache_stats());
    }
}

#[test]
fn replay_reconstructs_the_run_without_evaluating() {
    let dir = scratch_dir("replay");
    let cfg = store_config("fp8-gemm", 23, 20, 1, false, &dir);
    let mut run = ScientistRun::new(cfg).unwrap();
    run.run_to_completion().unwrap();
    let replayed = store::replay(&dir).expect("replay");
    assert!(!replayed.torn_tail);
    assert_eq!(replayed.workload, "fp8-gemm");
    assert_eq!(replayed.population.members(), run.population.members());
    assert_eq!(replayed.submissions, run.platform.submissions());
    let render = |logs: &[gpu_kernel_scientist::scientist::IterationLog]| -> Vec<String> {
        logs.iter().map(report::render_iteration).collect()
    };
    assert_eq!(render(&replayed.logs), render(&run.logs));
    assert_eq!(replayed.curve.points, run.curve.points);
}

#[test]
fn resume_of_a_completed_run_recomputes_the_same_outcome() {
    let dir = scratch_dir("done");
    let cfg = store_config("row-softmax", 29, 18, 1, true, &dir);
    let mut run = ScientistRun::new(cfg).unwrap();
    let out = run.run_to_completion().unwrap();
    let mut again = ScientistRun::resume(&dir).unwrap();
    let out2 = again.run_to_completion().unwrap();
    assert_bit_identical("completed rerun", &run, &out, &again, &out2);
}

#[test]
fn mixed_version_ledger_replays_with_missing_profiles() {
    // Backward compat (DESIGN.md §11): journals written before the
    // profile layer carry no `profile` key on `exp` records. Rewrite
    // every other exp line of a fresh store to that pre-profile wire
    // format — the mixed-version ledger must parse (stripped records
    // as `profile: None`) and replay to the exact same run (profiles
    // are derived state, never trajectory-bearing). Only replay is in
    // scope: a genuinely pre-profile *store* carries a VERSION-3
    // checkpoint, which resume version-rejects up front by design —
    // and replay is the path that reads the full journal.
    use gpu_kernel_scientist::store::{journal, JournalRecord};
    let dir = scratch_dir("mixed");
    let cfg = store_config("fp8-gemm", 37, 18, 1, false, &dir);
    let mut run = ScientistRun::new(cfg).unwrap();
    let out = run.run_to_completion().unwrap();

    let path = dir.join(store::JOURNAL_FILE);
    let text = std::fs::read_to_string(&path).unwrap();
    // the profile value is a flat object (no nested braces) or null,
    // so the first '}' after the key closes it
    let strip_profile = |line: &str| -> String {
        let key = ",\"profile\":";
        let Some(start) = line.find(key) else {
            panic!("exp line without a profile key: {line}");
        };
        let rest = &line[start + key.len()..];
        let len = if rest.starts_with('{') {
            rest.find('}').expect("flat profile object") + 1
        } else if rest.starts_with("null") {
            4
        } else {
            panic!("unexpected profile value: {rest}");
        };
        format!("{}{}", &line[..start], &rest[len..])
    };
    let mut exp_seen = 0usize;
    let mut rewritten = String::new();
    for line in text.lines() {
        if line.contains("\"t\":\"exp\"") {
            exp_seen += 1;
            if exp_seen % 2 == 1 {
                rewritten.push_str(&strip_profile(line));
                rewritten.push('\n');
                continue;
            }
        }
        rewritten.push_str(line);
        rewritten.push('\n');
    }
    assert!(exp_seen > 2, "run too small to mix versions");
    std::fs::write(&path, &rewritten).unwrap();

    // stripped records parse with profile None; the untouched ones
    // keep theirs (every successfully-estimated genome carries one)
    let (records, torn) = journal::parse_journal(&rewritten).unwrap();
    assert!(!torn);
    let mut seen = 0usize;
    let mut kept_some = 0usize;
    for r in &records {
        if let JournalRecord::Exp(e) = r {
            seen += 1;
            if seen % 2 == 1 {
                assert!(e.profile.is_none(), "stripped record kept a profile");
            } else if e.profile.is_some() {
                kept_some += 1;
            }
        }
    }
    assert!(kept_some > 0, "no untouched record carried a profile");

    let replayed = store::replay(&dir).expect("mixed-version replay");
    assert!(!replayed.torn_tail);
    assert_eq!(replayed.population.members(), run.population.members());
    assert_eq!(replayed.curve.points, out.curve.points);
    assert_eq!(replayed.submissions, out.submissions);
    let render = |logs: &[gpu_kernel_scientist::scientist::IterationLog]| -> Vec<String> {
        logs.iter().map(report::render_iteration).collect()
    };
    assert_eq!(
        render(&replayed.logs),
        render(&run.logs),
        "mixed-version ledger: iteration transcripts"
    );
}

#[test]
fn resume_under_federation_is_bit_identical() {
    // Crash-and-resume a run whose submissions are served from the
    // federated archive (DESIGN.md §12). Restore must NOT replay fed
    // journal entries against the backend (no lane ever evaluated
    // them), and the re-attached archive must keep serving the
    // continuation — counters included.
    let fed_dir = scratch_dir("fed-archive");
    let mut seed_cfg = RunConfig::default()
        .with_workload("fp8-gemm")
        .with_seed(7)
        .with_budget(20);
    seed_cfg.noise_sigma = 0.0; // fed hits never advance the noise stream
    seed_cfg.federation_dir = Some(fed_dir.display().to_string());
    let mut seeder = ScientistRun::new(seed_cfg).unwrap();
    seeder.run_to_completion().unwrap();

    let full_dir = scratch_dir("fed-full");
    let crash_dir = scratch_dir("fed-crash");
    let mk = |dir: &Path| {
        let mut cfg = store_config("fp8-gemm", 7, 20, 1, false, dir);
        cfg.noise_sigma = 0.0;
        cfg.federation_dir = Some(fed_dir.display().to_string());
        // keep the archive fixed: neither leg may republish under the
        // other's feet
        cfg.federation_read_only = true;
        cfg
    };
    let mut full = ScientistRun::new(mk(&full_dir)).unwrap();
    let full_out = full.run_to_completion().unwrap();
    assert!(
        full_out.federation.unwrap().hits > 0,
        "the archive must actually serve this configuration"
    );

    let mut crash_cfg = mk(&crash_dir);
    crash_cfg.halt_after = Some(11);
    let mut crashed = ScientistRun::new(crash_cfg).unwrap();
    let _ = crashed.run_to_completion().unwrap();
    assert!(crashed.halted());
    drop(crashed);

    let mut resumed = ScientistRun::resume(&crash_dir).unwrap();
    let resumed_out = resumed.run_to_completion().unwrap();
    assert_bit_identical("federated resume", &full, &full_out, &resumed, &resumed_out);
    assert_eq!(
        full_out.federation, resumed_out.federation,
        "fed hit counters survive the crash/restore cycle"
    );
}

#[test]
fn chaos_resume_with_a_retry_in_flight_is_bit_identical() {
    // The PR-10 referee (DESIGN.md §14): crash a fault-injected
    // pipeline run while the recovery layer has work pending — a
    // queued backoff retry and/or a reattachable in-flight dispatch —
    // and the resumed run must still match the uninterrupted chaos run
    // bit for bit: ledger (fault-class entries included), retry
    // counters, fault stats, wall clock. Several halt points so at
    // least one checkpoint catches a retry (attempt > 0) pending.
    let mk = |dir: &Path| {
        let mut cfg = store_config("fp8-gemm", 43, 26, 2, true, dir);
        cfg.faults.enabled = true;
        cfg.faults.transient = 0.30; // chaos hot enough to retry often
        cfg.faults.backoff_base_s = 5.0; // requeues re-dispatch quickly
        cfg.faults.quarantine_after = 10; // keep both lanes alive
        cfg
    };
    let full_dir = scratch_dir("chaos-full");
    let mut full = ScientistRun::new(mk(&full_dir)).unwrap();
    let full_out = full.run_to_completion().unwrap();
    let summary = full_out.faults.clone().expect("chaos run carries fault state");
    assert!(
        summary.retries > 0,
        "the fault rate must actually trigger retries: {summary:?}"
    );
    let mut any_pending_retry = false;
    for halt_after in [8u64, 10, 12, 14, 16] {
        let crash_dir = scratch_dir("chaos-crash");
        let mut crash_cfg = mk(&crash_dir);
        crash_cfg.halt_after = Some(halt_after);
        let mut crashed = ScientistRun::new(crash_cfg).unwrap();
        let _ = crashed.run_to_completion().unwrap();
        assert!(crashed.halted(), "halt={halt_after}");
        drop(crashed);
        let cp = store::Checkpoint::load(&crash_dir).unwrap();
        any_pending_retry |= cp.pending.iter().any(|p| p.attempt > 0);
        let mut resumed = ScientistRun::resume(&crash_dir).unwrap();
        let resumed_out = resumed.run_to_completion().unwrap();
        assert_bit_identical(
            &format!("chaos halt={halt_after}"),
            &full,
            &full_out,
            &resumed,
            &resumed_out,
        );
        assert_eq!(
            full_out.faults, resumed_out.faults,
            "halt={halt_after}: fault stats and recovery counters survive resume"
        );
    }
    assert!(
        any_pending_retry,
        "no halt point caught a backoff retry pending in a checkpoint — the \
         resume-mid-retry path went untested; retune halt_after/fault rates"
    );
}

#[test]
fn resume_without_a_store_is_a_clear_error() {
    let dir = scratch_dir("empty");
    let err = ScientistRun::resume(&dir).unwrap_err();
    assert!(err.contains("checkpoint"), "{err}");
}

#[test]
fn campaign_store_is_resumable_per_workload() {
    use gpu_kernel_scientist::scientist::campaign::{
        resume_campaign, run_campaign, CampaignConfig,
    };
    let full_dir = scratch_dir("camp-full");
    let crash_dir = scratch_dir("camp-crash");
    let base = |dir: &Path| {
        let mut cfg = RunConfig::default().with_seed(31).with_budget(16);
        cfg.store_dir = Some(dir.display().to_string());
        cfg
    };
    let workloads: Vec<String> =
        workloads::registry().iter().map(|w| w.name().to_string()).collect();
    let full = run_campaign(&CampaignConfig {
        workloads: workloads.clone(),
        base: base(&full_dir),
    })
    .unwrap();
    // crash every member at half budget, then resume the campaign
    let mut crash_base = base(&crash_dir);
    crash_base.halt_after = Some(8);
    let _ = run_campaign(&CampaignConfig {
        workloads: workloads.clone(),
        base: crash_base,
    })
    .unwrap();
    let resumed = resume_campaign(&crash_dir, None).unwrap();
    assert_eq!(full.results.len(), resumed.results.len());
    for (a, b) in full.results.iter().zip(&resumed.results) {
        assert_eq!(a.workload, b.workload);
        assert_eq!(a.outcome.best_id, b.outcome.best_id, "{}", a.workload);
        assert_eq!(
            a.outcome.best_geomean_us, b.outcome.best_geomean_us,
            "{}",
            a.workload
        );
        assert_eq!(a.outcome.submissions, b.outcome.submissions, "{}", a.workload);
        assert_eq!(a.cache_stats, b.cache_stats, "{}", a.workload);
    }
}
