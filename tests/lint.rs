//! The lint test layer (DESIGN.md §13):
//!
//! 1. **Off means off.** With `[lint]` absent or explicitly disabled
//!    (the default), every workload × both schedulers must produce
//!    runs bit-identical to a build that never had the analyzer — the
//!    TOML section itself must be inert while both knobs are false.
//! 2. **On means deterministic.** Gated + guided runs are a pure
//!    function of (seed, config): running twice is bit-identical,
//!    under both schedulers.
//! 3. **The Error set is the reject set.** On real trajectories, a
//!    member failed at the platform's compile gate iff the analyzer
//!    reports at least one `Severity::Error` for its genome.
//! 4. **Gate rejects are ledgered, never submitted.** Every rejected
//!    child appears in the population as a lint-gate compile failure,
//!    the counters account for them exactly, and no compile failure
//!    ever reaches the platform's submission log while the gate is on.

use gpu_kernel_scientist::analysis;
use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::gpu::MI300;
use gpu_kernel_scientist::population::EvalOutcome;
use gpu_kernel_scientist::test_support as ts;
use gpu_kernel_scientist::workload::{self, Workload};

/// The marker `record_lint_reject` stamps into the ledger.
const GATE_MSG: &str = "rejected by the lint gate";

/// Raise the surrogate's infidelity so the writer's repair loop leaks
/// invalid children at a useful rate (same knobs the e2e robustness
/// test uses) — without this, tiny budgets rarely exercise the gate.
fn spicy(mut cfg: RunConfig) -> RunConfig {
    cfg.llm.rubric_infidelity = 0.3;
    cfg.llm.temperature = 2.0;
    cfg
}

#[test]
fn disabled_lint_is_bit_identical_for_every_workload_and_scheduler() {
    // the control config parses a `[lint]` TOML section with both
    // knobs false: the section's presence must change nothing
    for w in workload::registry() {
        let name = w.name();
        for pipeline in [false, true] {
            let base = {
                let mut cfg = ts::tiny_run_config(9, 22).with_workload(name);
                cfg.eval_parallelism = if pipeline { 3 } else { 1 };
                cfg.pipeline = pipeline;
                cfg
            };
            let knobbed = {
                let parsed = RunConfig::from_toml("[lint]\ngate = false\nguided = false\n")
                    .expect("lint section parses");
                assert!(!parsed.lint_gate && !parsed.lint_guided);
                let mut cfg = parsed.with_seed(9).with_budget(22).with_workload(name);
                cfg.eval_parallelism = base.eval_parallelism;
                cfg.pipeline = pipeline;
                cfg
            };
            let (run_a, out_a) = ts::run_scientist(base);
            let (run_b, out_b) = ts::run_scientist(knobbed);
            let tag = format!("{name} pipeline={pipeline}");
            assert_eq!(ts::trajectory(&run_a), ts::trajectory(&run_b), "{tag}");
            assert_eq!(out_a.best_id, out_b.best_id, "{tag}");
            assert_eq!(out_a.best_geomean_us, out_b.best_geomean_us, "{tag}");
            assert_eq!(out_a.submissions, out_b.submissions, "{tag}");
            assert_eq!(out_a.wall_clock_s, out_b.wall_clock_s, "{tag}");
            assert_eq!(out_a.pipeline, out_b.pipeline, "{tag}");
            assert_eq!(out_a.pipeline.linted, 0, "{tag}: gate ran while off");
            assert_eq!(out_a.pipeline.lint_rejected, 0, "{tag}");
            assert!(
                !run_a
                    .population
                    .members()
                    .iter()
                    .any(|m| matches!(&m.outcome, EvalOutcome::CompileFailure(r) if r.contains(GATE_MSG))),
                "{tag}: gate reject in an ungated ledger"
            );
        }
    }
}

#[test]
fn gated_and_guided_runs_are_reproducible_per_scheduler() {
    for pipeline in [false, true] {
        let run_once = || {
            let mut cfg = spicy(ts::tiny_run_config(29, 30))
                .with_lint_gate(true)
                .with_lint_guided(true);
            cfg.pipeline = pipeline;
            cfg.eval_parallelism = if pipeline { 3 } else { 1 };
            let (run, o) = ts::run_scientist(cfg);
            (ts::trajectory(&run), o.best_id, o.best_geomean_us, o.pipeline)
        };
        assert_eq!(run_once(), run_once(), "gated+guided pipeline={pipeline}");
    }
}

#[test]
fn guided_alone_is_reproducible_and_counts_nothing() {
    // guidance without the gate: priors shift, but the gate counters
    // must stay untouched and the run must still be pure in (seed, cfg)
    let run_once = || {
        let cfg = spicy(ts::tiny_run_config(17, 26)).with_lint_guided(true);
        let (run, o) = ts::run_scientist(cfg);
        (ts::trajectory(&run), o.best_geomean_us, o.pipeline)
    };
    let a = run_once();
    assert_eq!(a, run_once(), "guided-only run diverged");
    assert_eq!(a.2.linted, 0, "guidance alone must not run the gate");
    assert_eq!(a.2.lint_rejected, 0);
}

#[test]
fn lint_errors_equal_the_platform_reject_set_on_real_trajectories() {
    // ungated runs: whatever the platform's compile gate rejected, the
    // analyzer must flag as an Error on the same genome — and nothing
    // else. Incorrect-result members (numeric hazards) must lint clean
    // of errors: the analyzer is static and must not claim them.
    for w in workload::registry() {
        let name = w.name();
        let cfg = spicy(ts::tiny_run_config(4, 40).with_workload(name));
        let (run, _) = ts::run_scientist(cfg);
        for m in run.population.members() {
            let diags = analysis::lint(&m.genome, &MI300, run.workload.as_ref());
            let flagged = analysis::has_error(&diags);
            match &m.outcome {
                EvalOutcome::CompileFailure(reason) => assert!(
                    flagged,
                    "{name} {}: platform rejected ({reason}) but lint sees no error",
                    m.id
                ),
                _ => assert!(
                    !flagged,
                    "{name} {}: lint errors {:?} on a genome the platform accepted",
                    m.id,
                    analysis::error_codes(&diags)
                ),
            }
        }
    }
}

#[test]
fn gate_rejects_are_ledgered_and_never_reach_the_platform() {
    for w in workload::registry() {
        let name = w.name();
        for pipeline in [false, true] {
            let mut cfg = spicy(ts::tiny_run_config(41, 32).with_workload(name))
                .with_lint_gate(true);
            cfg.pipeline = pipeline;
            cfg.eval_parallelism = if pipeline { 2 } else { 1 };
            let (run, out) = ts::run_scientist(cfg);
            let s = &out.pipeline;
            let tag = format!("{name} pipeline={pipeline}");
            assert!(s.linted > 0, "{tag}: gate never checked a child");
            assert!(s.lint_rejected <= s.linted, "{tag}");
            let n_seeds = w.starting_population().len() as u64;
            // every recorded non-seed member passed through the gate
            // (quota-dropped plans may be checked but never recorded)
            assert!(
                s.linted >= run.population.len() as u64 - n_seeds,
                "{tag}: ledgered children the gate never saw"
            );
            let gate_rejects = run
                .population
                .members()
                .iter()
                .filter(|m| {
                    matches!(&m.outcome, EvalOutcome::CompileFailure(r) if r.contains(GATE_MSG))
                })
                .count() as u64;
            assert_eq!(gate_rejects, s.lint_rejected, "{tag}: counter vs ledger");
            // completeness: with the gate on, nothing doomed may reach
            // the platform — its log must hold no compile failure
            assert!(
                !run.platform
                    .log()
                    .iter()
                    .any(|r| matches!(r.outcome, EvalOutcome::CompileFailure(_))),
                "{tag}: a doomed genome slipped past the gate"
            );
            // and every ledgered compile failure is a gate reject
            for m in run.population.members() {
                if let EvalOutcome::CompileFailure(reason) = &m.outcome {
                    assert!(
                        reason.contains(GATE_MSG),
                        "{tag} {}: platform compile failure in a gated run: {reason}",
                        m.id
                    );
                }
            }
        }
    }
}
