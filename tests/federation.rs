//! Federated-archive suite (DESIGN.md §12): the cross-run eval cache,
//! warm-start elite seeding, and indexed binary journal segments.
//!
//! The guarantees locked here:
//!
//! * **off means off** — with no `[federation]` config the run is
//!   bit-identical to one from a build without the layer, and an
//!   *empty* attached archive is equally inert, for every registered
//!   workload under both schedulers;
//! * **a second identical run burns zero evaluations** — every
//!   committed submission is served from the archive with genuine
//!   quota/wall-clock accounting, so the trajectory, leaderboard, and
//!   cache stats are identical to the first run's;
//! * **warm-start seeding is deterministic** and surfaces its count in
//!   the run outcome;
//! * **segments are interchangeable with JSONL** — `replay` renders
//!   the same run before and after `compact`, and torn or tampered
//!   segments are rejected, never silently truncated.

use std::path::Path;

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::report;
use gpu_kernel_scientist::scientist::ScientistRun;
use gpu_kernel_scientist::store::{self, config_digest, segment, FederationSnapshot};
use gpu_kernel_scientist::test_support::{noiseless_config, scratch_dir, trajectory};
use gpu_kernel_scientist::workload::{registry, Workload};

/// A federated variant of [`noiseless_config`]. Noiseless because the
/// run-twice tests compare a fed-served second run against a genuinely
/// evaluated first run: archive hits never advance the backend noise
/// stream, so only exact measurements make the final leaderboard
/// rescoring comparable.
fn fed_config(workload: &str, seed: u64, budget: u64, dir: &Path) -> RunConfig {
    noiseless_config(workload, seed, budget).with_federation(&dir.display().to_string())
}

#[test]
fn an_empty_archive_is_inert_for_every_workload_and_scheduler() {
    // off-vs-on-but-empty bit identity: attaching a federation dir with
    // nothing in it must not perturb the trajectory, clocks, or cache
    // stats — the off-means-off guarantee plus its boundary case
    for w in registry() {
        for (pipeline, lanes) in [(false, 1u32), (true, 2)] {
            let label = format!(
                "{} {}",
                w.name(),
                if pipeline { "pipeline" } else { "lockstep" }
            );
            let base = RunConfig::default()
                .with_workload(w.name())
                .with_seed(11)
                .with_budget(14)
                .with_parallelism(lanes)
                .with_pipeline(pipeline);
            let mut plain = ScientistRun::new(base.clone()).unwrap();
            let plain_out = plain.run_to_completion().unwrap();
            assert!(plain_out.federation.is_none(), "{label}: off carries no stats");

            let dir = scratch_dir("fed-empty");
            let fed_cfg = base.with_federation(&dir.display().to_string());
            let mut fed = ScientistRun::new(fed_cfg).unwrap();
            let fed_out = fed.run_to_completion().unwrap();
            assert_eq!(trajectory(&plain), trajectory(&fed), "{label}: trajectory");
            assert_eq!(plain_out.best_id, fed_out.best_id, "{label}");
            assert_eq!(plain_out.best_geomean_us, fed_out.best_geomean_us, "{label}");
            assert_eq!(plain_out.wall_clock_s, fed_out.wall_clock_s, "{label}");
            assert_eq!(
                plain.platform.cache_stats(),
                fed.platform.cache_stats(),
                "{label}: cache stats"
            );
            let stats = fed_out.federation.expect("federation on carries stats");
            assert_eq!(stats.hits, 0, "{label}: an empty archive cannot hit");
            assert_eq!(stats.warm_start_injected, 0, "{label}: k defaults to 0");
            // the completed run published its results for future runs
            let published = std::fs::read_dir(&dir).unwrap().count();
            assert_eq!(published, 1, "{label}: one run file published");
        }
    }
}

#[test]
fn a_second_identical_run_is_served_entirely_from_the_archive() {
    for (pipeline, lanes) in [(false, 1u32), (true, 2)] {
        let label = if pipeline { "pipeline" } else { "lockstep" };
        let dir = scratch_dir("fed-twice");
        let mk = || {
            let mut cfg = fed_config("fp8-gemm", 7, 20, &dir)
                .with_parallelism(lanes)
                .with_pipeline(pipeline);
            cfg.store_dir = Some(scratch_dir("fed-twice-store").display().to_string());
            cfg
        };

        let mut first = ScientistRun::new(mk()).unwrap();
        let first_out = first.run_to_completion().unwrap();
        assert_eq!(
            first_out.federation.unwrap().hits,
            0,
            "{label}: nothing to hit on the first run"
        );
        let first_store = first.config.store_dir.clone().unwrap();

        let mut second = ScientistRun::new(mk()).unwrap();
        let second_out = second.run_to_completion().unwrap();
        let hits = second_out.federation.unwrap().hits;
        assert!(hits > 0, "{label}: the archive must serve hits");
        // the acceptance bar: zero re-evaluations — every committed
        // submission of the second run came from the archive
        assert_eq!(
            hits,
            second.platform.submissions(),
            "{label}: every submission fed-served (100% cross-run hit rate)"
        );
        assert_eq!(trajectory(&first), trajectory(&second), "{label}: trajectory");
        assert_eq!(first_out.best_id, second_out.best_id, "{label}");
        assert_eq!(
            first_out.best_geomean_us, second_out.best_geomean_us,
            "{label}: identical leaderboard"
        );
        assert_eq!(first_out.leaderboard_us, second_out.leaderboard_us, "{label}");
        assert_eq!(first_out.submissions, second_out.submissions, "{label}");
        assert_eq!(
            first_out.wall_clock_s, second_out.wall_clock_s,
            "{label}: fed hits bill genuine lane time"
        );
        assert_eq!(
            first.platform.cache_stats(),
            second.platform.cache_stats(),
            "{label}: fed hits count as misses exactly like genuine evals"
        );
        // hit provenance reaches the journal: the second run's ledger
        // marks fed entries, the first run's has none
        let journal_of = |dir: &str| {
            std::fs::read_to_string(Path::new(dir).join(store::JOURNAL_FILE)).unwrap()
        };
        assert!(
            !journal_of(&first_store).contains("\"federated\":true"),
            "{label}: first run journals no fed entries"
        );
        assert!(
            journal_of(&second.config.store_dir.clone().unwrap())
                .contains("\"federated\":true"),
            "{label}: second run journals hit provenance"
        );
        // publication is idempotent: the identical second run overwrote
        // its own file — the archive still holds exactly one
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 1, "{label}");
    }
}

#[test]
fn warm_start_seeding_is_deterministic_and_reported() {
    // seed the archive from one campaign, then warm-start a different
    // seed's run with its elites
    let dir = scratch_dir("fed-warm");
    let mut seeder = ScientistRun::new(fed_config("fp8-gemm", 1, 20, &dir)).unwrap();
    seeder.run_to_completion().unwrap();

    let mk = || {
        // read-only so neither determinism leg perturbs the archive the
        // other loads
        let mut cfg = fed_config("fp8-gemm", 2, 24, &dir).with_warm_start_k(3);
        cfg.federation_read_only = true;
        cfg
    };
    let mut a = ScientistRun::new(mk()).unwrap();
    let a_out = a.run_to_completion().unwrap();
    let injected = a_out.federation.unwrap().warm_start_injected;
    assert!(injected >= 1, "the prior campaign's elites must transfer");
    assert!(injected <= 3, "never more than k");
    let labeled = a
        .population
        .members()
        .iter()
        .filter(|m| m.experiment.starts_with("federated warm-start elite"))
        .count() as u64;
    assert_eq!(labeled, injected, "the count matches the ledger's labels");

    let mut b = ScientistRun::new(mk()).unwrap();
    let b_out = b.run_to_completion().unwrap();
    assert_eq!(trajectory(&a), trajectory(&b), "warm-start is deterministic");
    assert_eq!(a_out.federation, b_out.federation);
    assert_eq!(a_out.best_id, b_out.best_id);
    assert_eq!(a_out.best_geomean_us, b_out.best_geomean_us);

    // read-only held: the archive still contains only the seeder's file
    assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 1);
}

#[test]
fn config_digest_tracks_eval_knobs_and_ignores_scheduling() {
    let base = RunConfig::default();
    let d = config_digest(&base, 1);
    // excluded: knobs that cannot change what an evaluation returns
    assert_eq!(d, config_digest(&base.clone().with_seed(99), 1), "seed");
    assert_eq!(
        d,
        config_digest(&base.clone().with_parallelism(4).with_pipeline(true), 1),
        "scheduling"
    );
    assert_eq!(d, config_digest(&base.clone().with_budget(999), 1), "budget");
    // included: every eval-relevant knob flips the digest (the negative
    // knob-flip guarantee — stale entries must stop matching)
    let mut reps = base.clone();
    reps.reps_per_config += 1;
    assert_ne!(d, config_digest(&reps, 1), "reps");
    let mut noise = base.clone();
    noise.noise_sigma += 0.125;
    assert_ne!(d, config_digest(&noise, 1), "noise");
    let mut cache = base.clone();
    cache.eval_cache = !cache.eval_cache;
    assert_ne!(d, config_digest(&cache, 1), "cache");
    assert_ne!(d, config_digest(&base.clone().with_screen(4, 0.5), 1), "screen");
    let mut guided = base.clone();
    guided.profile_guided = true;
    assert_ne!(d, config_digest(&guided, 1), "profile");
    assert_ne!(d, config_digest(&base, 2), "cost-model version");
}

#[test]
fn replay_renders_identically_before_and_after_compaction() {
    let dir = scratch_dir("fed-compact");
    let mut cfg = noiseless_config("fp8-gemm", 23, 18);
    cfg.store_dir = Some(dir.display().to_string());
    let mut run = ScientistRun::new(cfg).unwrap();
    run.run_to_completion().unwrap();

    let before = store::replay(&dir).expect("jsonl replay");
    assert!(store::compact_run_store(&dir).unwrap());
    assert!(!dir.join(store::JOURNAL_FILE).exists());
    assert!(dir.join(store::SEGMENT_FILE).exists());
    let after = store::replay(&dir).expect("segment replay");

    assert_eq!(before.population.members(), after.population.members());
    assert_eq!(before.curve.points, after.curve.points);
    assert_eq!(before.submissions, after.submissions);
    let render = |logs: &[gpu_kernel_scientist::scientist::IterationLog]| -> Vec<String> {
        logs.iter().map(report::render_iteration).collect()
    };
    assert_eq!(render(&before.logs), render(&after.logs));
    // replay is read-only: the segment survives it
    assert!(dir.join(store::SEGMENT_FILE).exists());
    assert!(!dir.join(store::JOURNAL_FILE).exists());
}

#[test]
fn torn_and_tampered_segments_are_rejected() {
    let dir = scratch_dir("fed-torn");
    let mut cfg = noiseless_config("row-softmax", 31, 14);
    cfg.store_dir = Some(dir.display().to_string());
    let mut run = ScientistRun::new(cfg).unwrap();
    run.run_to_completion().unwrap();
    assert!(store::compact_run_store(&dir).unwrap());
    let seg = dir.join(store::SEGMENT_FILE);
    let good = std::fs::read(&seg).unwrap();

    // torn: a truncated segment fails the length check up front
    std::fs::write(&seg, &good[..good.len() - 7]).unwrap();
    assert!(segment::open_index(&seg).is_err(), "torn index must not open");
    assert!(segment::read_lines(&seg).is_err(), "torn records must not read");
    assert!(store::replay(&dir).is_err(), "replay must refuse a torn segment");

    // tampered: flip one record byte — the records CRC catches it even
    // though the header and index are intact
    let mut bad = good.clone();
    bad[64] ^= 0x01;
    std::fs::write(&seg, &bad).unwrap();
    assert!(segment::read_lines(&seg).is_err(), "corrupt records must not read");

    // restored bytes read fine again
    std::fs::write(&seg, &good).unwrap();
    assert!(store::replay(&dir).is_ok());
}

#[test]
fn federation_snapshot_merges_jsonl_and_segment_run_files() {
    // a mixed archive — some runs compacted, some not — loads as one
    // snapshot with identical contents either way
    let dir = scratch_dir("fed-mixed");
    for seed in [3u64, 4] {
        let mut run = ScientistRun::new(fed_config("fp8-gemm", seed, 16, &dir)).unwrap();
        run.run_to_completion().unwrap();
    }
    let before = FederationSnapshot::load(&dir).unwrap();
    assert!(before.len() > 0);
    let compacted = store::federation::compact_dir(&dir).unwrap();
    assert_eq!(compacted, 2, "both run files compact");
    let after = FederationSnapshot::load(&dir).unwrap();
    assert_eq!(before.entries(), after.entries(), "compaction preserves the archive");
}
