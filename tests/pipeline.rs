//! The steady-state pipeline's equivalence + determinism matrix
//! (DESIGN.md §8, complements `tests/determinism.rs`):
//!
//! * at `parallelism = 1` the pipeline is the **degenerate lockstep
//!   case**: same agent-RNG and backend-RNG call sequences, hence a
//!   bit-identical trajectory, transcript, and wall clock — for every
//!   registered workload, with measurement noise on;
//! * at any lane count a pipeline run is a pure function of
//!   (seed, config): re-running it reproduces the trajectory exactly,
//!   and the eval cache is invisible to it (duplicates are replanned,
//!   never submitted);
//! * at `parallelism = 4` the pipeline keeps lanes busy that lockstep
//!   leaves idling at the batch barrier: strictly higher lane
//!   occupancy and a strictly shorter simulated wall clock on the
//!   fp8-gemm quickstart configuration.

use gpu_kernel_scientist::test_support as ts;
use gpu_kernel_scientist::workload::{self, Workload};

type Trajectory = Vec<(String, String)>;
type RunPoint = (Trajectory, String, f64, u64, f64);

fn run_point(
    workload: &str,
    seed: u64,
    budget: u64,
    lanes: u32,
    pipeline: bool,
    cache: bool,
) -> RunPoint {
    let mut cfg = ts::tiny_run_config(seed, budget).with_workload(workload);
    cfg.eval_parallelism = lanes;
    cfg.pipeline = pipeline;
    cfg.eval_cache = cache;
    let (run, outcome) = ts::run_scientist(cfg);
    (
        ts::trajectory(&run),
        outcome.best_id,
        outcome.best_geomean_us,
        outcome.submissions,
        outcome.wall_clock_s,
    )
}

#[test]
fn pipeline_at_one_lane_is_bit_identical_to_lockstep_for_every_workload() {
    for w in workload::registry() {
        let name = w.name();
        let lockstep_cfg = ts::tiny_run_config(11, 24).with_workload(name);
        let (lockstep_run, lockstep_out) = ts::run_scientist(lockstep_cfg);
        let pipeline_cfg = ts::pipeline_config(name, 11, 24, 1);
        let (pipeline_run, pipeline_out) = ts::run_scientist(pipeline_cfg);

        assert_eq!(
            ts::trajectory(&lockstep_run),
            ts::trajectory(&pipeline_run),
            "{name}: pipeline@1 must replay the lockstep trajectory bit for bit"
        );
        assert_eq!(lockstep_out.best_id, pipeline_out.best_id, "{name}");
        assert_eq!(
            lockstep_out.best_geomean_us, pipeline_out.best_geomean_us,
            "{name}"
        );
        assert_eq!(lockstep_out.submissions, pipeline_out.submissions, "{name}");
        assert_eq!(lockstep_out.wall_clock_s, pipeline_out.wall_clock_s, "{name}");
        assert_eq!(
            lockstep_run.platform.cache_stats(),
            pipeline_run.platform.cache_stats(),
            "{name}"
        );
        // same transcript: planning rounds and child attribution match
        assert_eq!(lockstep_run.logs.len(), pipeline_run.logs.len(), "{name}");
        for (a, b) in lockstep_run.logs.iter().zip(&pipeline_run.logs) {
            assert_eq!(a.submitted_ids, b.submitted_ids, "{name}");
            assert_eq!(a.chosen_experiments, b.chosen_experiments, "{name}");
            assert_eq!(a.selection.base_id, b.selection.base_id, "{name}");
        }
    }
}

#[test]
fn pipeline_trajectory_is_a_pure_function_of_seed_and_config() {
    // noisy runs, every workload, parallelism {1, 2, 4} (+ the CI
    // matrix lane count): virtual-clock completion order — not OS
    // scheduling — decides what the planner sees, so same-config runs
    // replay exactly
    let mut lanes = vec![1u32, 2, 4];
    let env = ts::env_parallelism();
    if !lanes.contains(&env) {
        lanes.push(env);
    }
    for w in workload::registry() {
        for &p in &lanes {
            let a = run_point(w.name(), 13, 24, p, true, true);
            let b = run_point(w.name(), 13, 24, p, true, true);
            assert_eq!(a, b, "{} diverged at parallelism={p}", w.name());
        }
    }
}

#[test]
fn pipeline_never_submits_duplicates_so_the_cache_is_invisible() {
    for w in workload::registry() {
        for p in [1u32, 4] {
            let (cached, ..) = run_point(w.name(), 13, 24, p, true, true);
            let (raw, ..) = run_point(w.name(), 13, 24, p, true, false);
            assert_eq!(
                cached, raw,
                "{} at parallelism={p}: cache on/off must not change the trajectory",
                w.name()
            );
        }
    }
}

#[test]
fn pipeline_saturates_lanes_that_lockstep_leaves_idle() {
    // the fp8-gemm quickstart configuration (seed 42, budget 30) on 4
    // lanes: lockstep submits <= 3 children per round and then waits at
    // the barrier, so at least one lane always idles; the pipeline
    // refills lanes the moment they free
    let run_mode = |pipeline: bool| {
        let mut cfg = ts::tiny_run_config(42, 30);
        cfg.eval_parallelism = 4;
        cfg.pipeline = pipeline;
        let (_, outcome) = ts::run_scientist(cfg);
        outcome
    };
    let lockstep = run_mode(false);
    let pipeline = run_mode(true);
    assert!(
        pipeline.pipeline.lane_occupancy > lockstep.pipeline.lane_occupancy,
        "pipeline occupancy {:.3} must strictly exceed lockstep {:.3}",
        pipeline.pipeline.lane_occupancy,
        lockstep.pipeline.lane_occupancy
    );
    // lockstep's 3-child rounds cannot fill 4 lanes
    assert!(
        lockstep.pipeline.lane_occupancy < 1.0,
        "lockstep at 4 lanes idles at the barrier ({:.3})",
        lockstep.pipeline.lane_occupancy
    );
    // simulated time per submission: the pipeline is strictly faster
    let lockstep_rate = lockstep.wall_clock_s / lockstep.submissions as f64;
    let pipeline_rate = pipeline.wall_clock_s / pipeline.submissions as f64;
    assert!(
        pipeline_rate < lockstep_rate,
        "pipeline {pipeline_rate:.1} s/submission vs lockstep {lockstep_rate:.1}"
    );
    // depth: the pipeline genuinely keeps several submissions in
    // flight, lockstep at one lane cannot
    assert!(pipeline.pipeline.mean_in_flight > 1.5);
    assert!(pipeline.pipeline.max_in_flight <= 4, "cap = lanes x 1");
}

#[test]
fn single_lane_pipeline_reports_saturated_lanes() {
    let cfg = ts::pipeline_config(workload::DEFAULT_WORKLOAD, 7, 20, 1);
    let (_, outcome) = ts::run_scientist(cfg);
    assert!(outcome.pipeline.pipelined);
    assert_eq!(outcome.pipeline.lanes, 1);
    assert!((outcome.pipeline.lane_occupancy - 1.0).abs() < 1e-12);
    assert!((outcome.pipeline.mean_in_flight - 1.0).abs() < 1e-12);
    assert_eq!(outcome.pipeline.max_in_flight, 1);
}
