//! The determinism matrix (extends the single-case check in
//! `tests/executor.rs` to every registered workload):
//!
//! For each workload in `workload::registry()`, the scientist
//! trajectory — the full population ledger of (genome fingerprint,
//! outcome) pairs — must be **bit-identical**
//!
//! * across `eval_parallelism ∈ {1, 2, 4}` (plus the CI matrix value
//!   from `GKS_TEST_PARALLELISM`), and
//! * with the eval cache on vs. off,
//!
//! for a fixed seed. The runs use a noiseless platform
//! (`noise_sigma = 0`): measurement jitter is the one *intended*
//! lane-count-dependent effect (each lane models an independent
//! competition server), so zeroing it exposes everything else —
//! partitioning, ordering, cache interactions — which must be exact.

use gpu_kernel_scientist::test_support as ts;
use gpu_kernel_scientist::workload::{self, Workload};

fn run_matrix_point(
    workload: &str,
    seed: u64,
    parallelism: u32,
    cache: bool,
) -> (Vec<(String, String)>, String, f64) {
    let mut cfg = ts::noiseless_config(workload, seed, 24);
    cfg.eval_parallelism = parallelism;
    cfg.eval_cache = cache;
    let (run, outcome) = ts::run_scientist(cfg);
    (ts::trajectory(&run), outcome.best_id, outcome.best_geomean_us)
}

#[test]
fn trajectory_is_invariant_across_parallelism_and_cache_for_every_workload() {
    for w in workload::registry() {
        let name = w.name();
        let (base_traj, base_best, base_score) = run_matrix_point(name, 13, 1, true);
        assert!(!base_traj.is_empty(), "{name}: empty trajectory");
        let mut lanes = vec![1, 2, 4];
        let env = ts::env_parallelism();
        if !lanes.contains(&env) {
            lanes.push(env);
        }
        for p in lanes {
            for cache in [true, false] {
                if p == 1 && cache {
                    continue; // the base point itself
                }
                let (traj, best, score) = run_matrix_point(name, 13, p, cache);
                assert_eq!(
                    traj, base_traj,
                    "{name}: trajectory diverged at parallelism={p} cache={cache}"
                );
                assert_eq!(best, base_best, "{name}: p={p} cache={cache}");
                assert_eq!(score, base_score, "{name}: p={p} cache={cache}");
            }
        }
    }
}

#[test]
fn profile_guided_off_is_bit_identical_to_default_for_every_workload_and_scheduler() {
    // off-means-off (DESIGN.md §11): a config that parsed
    // `[profile] guided = false` must drive the exact same trajectory
    // as one that never mentioned the profile section — for every
    // workload, under both schedulers. The profile layer computes its
    // reports unconditionally, so this locks in that computing them
    // perturbs nothing (no RNG draw, no quota, no ordering change).
    for w in workload::registry() {
        for pipeline in [false, true] {
            let point = |via_toml: bool| {
                let mut cfg = ts::noiseless_config(w.name(), 13, 24);
                cfg.pipeline = pipeline;
                if via_toml {
                    let knob = gpu_kernel_scientist::config::RunConfig::from_toml(
                        "[profile]\nguided = false\n",
                    )
                    .expect("knob parses");
                    cfg.profile_guided = knob.profile_guided;
                }
                let (run, o) = ts::run_scientist(cfg);
                assert!(
                    o.profile_mix.is_none(),
                    "{}: an unguided outcome must carry no bottleneck mix",
                    w.name()
                );
                (ts::trajectory(&run), o.best_id, o.best_geomean_us)
            };
            assert_eq!(
                point(false),
                point(true),
                "{}: [profile] guided=false diverged from default (pipeline={pipeline})",
                w.name()
            );
        }
    }
}

#[test]
fn profile_guided_runs_are_reproducible_and_the_knob_is_alive() {
    // guided-on must stay deterministic per seed, carry a populated
    // bottleneck mix, and actually steer at least one workload's
    // trajectory away from the unguided run (a knob that changes
    // nothing when on is dead code)
    let mut any_diverged = false;
    for w in workload::registry() {
        let point = |guided: bool| {
            let mut cfg = ts::noiseless_config(w.name(), 13, 24);
            cfg.profile_guided = guided;
            let (run, o) = ts::run_scientist(cfg);
            (ts::trajectory(&run), o.best_id, o.profile_mix)
        };
        let on = point(true);
        let again = point(true);
        assert_eq!(on.0, again.0, "{}: guided trajectory not reproducible", w.name());
        assert_eq!(on.1, again.1, "{}", w.name());
        let mix = on.2.as_ref().expect("guided outcome carries a mix");
        assert!(mix.total() > 0, "{}: guided mix counted nothing", w.name());
        if on.0 != point(false).0 {
            any_diverged = true;
        }
    }
    assert!(
        any_diverged,
        "profile guidance never changed any workload's trajectory"
    );
}

#[test]
fn trajectories_differ_between_workloads() {
    // the matrix above would pass vacuously if every workload produced
    // the same ledger; make sure the families genuinely diverge
    let mut trajectories = Vec::new();
    for w in workload::registry() {
        let (t, _, _) = run_matrix_point(w.name(), 13, 1, true);
        trajectories.push((w.name(), t));
    }
    for i in 0..trajectories.len() {
        for j in (i + 1)..trajectories.len() {
            assert_ne!(
                trajectories[i].1, trajectories[j].1,
                "{} and {} produced identical trajectories",
                trajectories[i].0, trajectories[j].0
            );
        }
    }
}

#[test]
fn ci_matrix_lane_count_runs_are_reproducible_with_noise() {
    // exercises exactly the CI matrix's lane count on a *noisy*
    // platform for every workload — at GKS_TEST_PARALLELISM=4 this
    // (noisy, 4-lane, full-loop) configuration runs nowhere else in
    // the suite, which is what makes the CI matrix leg meaningful
    let p = ts::env_parallelism();
    for w in workload::registry() {
        let run_once = || {
            let mut cfg = ts::tiny_run_config(17, 21).with_workload(w.name());
            cfg.eval_parallelism = p;
            let (run, o) = ts::run_scientist(cfg);
            (ts::trajectory(&run), o.best_id, o.best_geomean_us)
        };
        assert_eq!(run_once(), run_once(), "{} at {p} lanes", w.name());
    }
}

#[test]
fn noisy_single_lane_runs_stay_reproducible_per_seed() {
    // with noise back on, same-seed same-lane-count runs must still be
    // bit-identical (the original executor.rs guarantee, per workload)
    for w in workload::registry() {
        let run_once = || {
            let cfg = ts::tiny_run_config(4, 18).with_workload(w.name());
            let (run, outcome) = ts::run_scientist(cfg);
            (ts::trajectory(&run), outcome.best_id, outcome.best_geomean_us)
        };
        assert_eq!(run_once(), run_once(), "{}", w.name());
    }
}

#[test]
fn every_workload_passes_an_end_to_end_smoke() {
    // acceptance: each registered workload completes a full loop with a
    // consistent ledger and a best kernel no worse than its seeds
    for w in workload::registry() {
        let name = w.name();
        let cfg = ts::tiny_run_config(2, 30).with_workload(name);
        let (run, outcome) = ts::run_scientist(cfg);
        assert_eq!(outcome.workload, name);
        assert!(outcome.submissions <= 30, "{name}");
        assert!(
            outcome.best_geomean_us.is_finite() && outcome.best_geomean_us > 0.0,
            "{name}"
        );
        // ledger consistency: one population row per submission, in
        // log order
        assert_eq!(
            run.platform.submissions() as usize,
            run.population.len(),
            "{name}"
        );
        for (rec, member) in run.platform.log().iter().zip(run.population.members()) {
            assert_eq!(rec.outcome, member.outcome, "{name}");
        }
        // the loop's best must at least match the best seed, and beat
        // the family's naive translation
        let n_seeds = w.starting_population().len();
        let best_seed = run
            .population
            .members()
            .iter()
            .take(n_seeds)
            .filter_map(|m| m.score())
            .fold(f64::INFINITY, f64::min);
        assert!(
            outcome.best_geomean_us <= best_seed,
            "{name}: best {} worse than best seed {best_seed}",
            outcome.best_geomean_us
        );
        let naive_score = run
            .population
            .members()
            .iter()
            .take(n_seeds)
            .find(|m| m.experiment.contains("naive"))
            .and_then(|m| m.score())
            .expect("every family seeds a naive translation");
        assert!(
            outcome.best_geomean_us < naive_score,
            "{name}: best {} does not beat naive {naive_score}",
            outcome.best_geomean_us
        );
        // the leaderboard geomean is scored on the workload's own basis
        assert!(outcome.leaderboard_us.is_some(), "{name}");
    }
}
