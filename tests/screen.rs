//! The screening test layer (DESIGN.md §10):
//!
//! 1. **Off means off.** With `[screen]` disabled (the default), every
//!    workload × both schedulers × several lane counts must produce
//!    runs bit-identical to a build that never had the tier — even
//!    when the (inert) screen knobs are set to non-default values.
//! 2. **On means deterministic.** With screening enabled, trajectories
//!    stay invariant across eval parallelism and cache on/off on a
//!    noiseless platform (lockstep), and same-config runs stay
//!    bit-identical under noise (pipeline).
//! 3. **Counters are conserved** and fully explain the submission
//!    ledger: every non-seed submission was promoted by the tier.

use gpu_kernel_scientist::test_support as ts;
use gpu_kernel_scientist::workload::{self, Workload};

#[test]
fn disabled_screening_is_bit_identical_for_every_workload_and_scheduler() {
    // the control config carries *non-default* screen knobs with the
    // tier disabled: proves the knobs are inert unless `enabled = true`
    for w in workload::registry() {
        let name = w.name();
        for pipeline in [false, true] {
            for lanes in 1..=3u32 {
                let base = {
                    let mut cfg = ts::tiny_run_config(9, 22).with_workload(name);
                    cfg.eval_parallelism = lanes;
                    cfg.pipeline = pipeline;
                    cfg
                };
                let knobbed = {
                    let mut cfg = base.clone();
                    cfg.screen_rung = 7;
                    cfg.screen_keep = 0.25;
                    assert!(!cfg.screen_enabled);
                    cfg
                };
                let (run_a, out_a) = ts::run_scientist(base);
                let (run_b, out_b) = ts::run_scientist(knobbed);
                let tag = format!("{name} pipeline={pipeline} lanes={lanes}");
                assert_eq!(ts::trajectory(&run_a), ts::trajectory(&run_b), "{tag}");
                assert_eq!(out_a.best_id, out_b.best_id, "{tag}");
                assert_eq!(out_a.best_geomean_us, out_b.best_geomean_us, "{tag}");
                assert_eq!(out_a.submissions, out_b.submissions, "{tag}");
                assert_eq!(out_a.wall_clock_s, out_b.wall_clock_s, "{tag}");
                assert_eq!(out_a.pipeline, out_b.pipeline, "{tag}");
                assert_eq!(out_a.pipeline.screened, 0, "{tag}: tier ran while off");
                assert_eq!(out_a.pipeline.screen_promoted, 0, "{tag}");
                assert_eq!(out_a.pipeline.screen_rejected, 0, "{tag}");
            }
        }
    }
}

#[test]
fn screened_lockstep_trajectory_is_invariant_across_parallelism_and_cache() {
    // the screen score is analytic (cost model only, no RNG, no
    // measurement), so on a noiseless platform the screened trajectory
    // must survive the same matrix the unscreened determinism suite runs
    for w in workload::registry() {
        let name = w.name();
        let run_point = |parallelism: u32, cache: bool| {
            let mut cfg = ts::noiseless_config(name, 13, 24).with_screen(4, 0.5);
            cfg.eval_parallelism = parallelism;
            cfg.eval_cache = cache;
            let (run, o) = ts::run_scientist(cfg);
            (ts::trajectory(&run), o.best_id, o.best_geomean_us, o.pipeline)
        };
        let base = run_point(1, true);
        assert!(!base.0.is_empty(), "{name}: empty trajectory");
        assert!(base.3.screened > 0, "{name}: screen tier never scored");
        let mut lanes = vec![1, 2, 4];
        let env = ts::env_parallelism();
        if !lanes.contains(&env) {
            lanes.push(env);
        }
        for p in lanes {
            for cache in [true, false] {
                if p == 1 && cache {
                    continue; // the base point itself
                }
                let point = run_point(p, cache);
                assert_eq!(
                    point, base,
                    "{name}: screened run diverged at parallelism={p} cache={cache}"
                );
            }
        }
    }
}

#[test]
fn screened_pipeline_runs_are_reproducible_per_lane_count() {
    // pipeline mode keeps its noise model; the guarantee under noise is
    // same-seed same-config bit-identity, per lane count
    for lanes in [1u32, 2, 4] {
        let run_once = || {
            let cfg = ts::screened_pipeline_config("fp8-gemm", 29, 30, lanes);
            let (run, o) = ts::run_scientist(cfg);
            (ts::trajectory(&run), o.best_id, o.best_geomean_us, o.pipeline)
        };
        assert_eq!(run_once(), run_once(), "screened pipeline at {lanes} lanes");
    }
}

#[test]
fn screened_pipeline_is_cache_invariant_when_noiseless() {
    let run_point = |cache: bool| {
        let mut cfg = ts::screened_pipeline_config("row-softmax", 5, 28, 2);
        cfg.noise_sigma = 0.0;
        cfg.eval_cache = cache;
        let (run, o) = ts::run_scientist(cfg);
        (ts::trajectory(&run), o.best_id, o.best_geomean_us, o.pipeline)
    };
    let on = run_point(true);
    assert!(on.3.screened > 0, "screen tier never scored");
    assert_eq!(on, run_point(false), "cache toggled the screened trajectory");
}

#[test]
fn screen_counters_are_conserved_and_explain_the_ledger() {
    // pipeline: every non-seed submission must have been promoted by
    // the tier, and nothing the tier saw may go unaccounted
    for w in workload::registry() {
        let name = w.name();
        let cfg = ts::screened_pipeline_config(name, 41, 32, 2);
        let (run, out) = ts::run_scientist(cfg);
        let s = &out.pipeline;
        assert!(s.screened > 0, "{name}: tier never scored");
        assert!(s.screen_rejected > 0, "{name}: keep=0.5 never rejected");
        assert_eq!(
            s.screened,
            s.screen_promoted + s.screen_rejected,
            "{name}: conservation (no pending work may survive the run)"
        );
        let n_seeds = w.starting_population().len() as u64;
        assert_eq!(
            run.population.len() as u64 - n_seeds,
            s.screen_promoted,
            "{name}: submitted children != promoted candidates"
        );
    }
}

#[test]
fn screened_lockstep_counters_are_conserved() {
    // lockstep rungs are batch-scoped (one rung per planned group), so
    // conservation must hold there too, with zero pending at the end
    let mut cfg = ts::noiseless_config("bf16-gemm", 3, 26).with_screen(4, 0.5);
    cfg.eval_parallelism = 2;
    let (run, out) = ts::run_scientist(cfg);
    let s = &out.pipeline;
    assert!(!s.pipelined);
    assert!(s.screened > 0);
    assert_eq!(s.screened, s.screen_promoted + s.screen_rejected);
    let n_seeds = workload::registry()
        .into_iter()
        .find(|w| w.name() == "bf16-gemm")
        .expect("registered workload")
        .starting_population()
        .len() as u64;
    assert_eq!(run.population.len() as u64 - n_seeds, s.screen_promoted);
}

#[test]
fn screening_prunes_but_never_worsens_the_best_on_a_noiseless_run() {
    // acceptance-level sanity: the analytic tier may only reject
    // candidates, and the survivors still improve on the seeds
    for w in workload::registry() {
        let name = w.name();
        let cfg = ts::noiseless_config(name, 17, 24).with_screen(4, 0.5);
        let (run, out) = ts::run_scientist(cfg);
        let n_seeds = w.starting_population().len();
        let best_seed = run
            .population
            .members()
            .iter()
            .take(n_seeds)
            .filter_map(|m| m.score())
            .fold(f64::INFINITY, f64::min);
        assert!(
            out.best_geomean_us <= best_seed,
            "{name}: screened best {} worse than best seed {best_seed}",
            out.best_geomean_us
        );
    }
}
