//! The fault-injection test layer (DESIGN.md §14):
//!
//! 1. **Off means off.** With `[faults]` absent — or present but
//!    disabled, every *other* knob cranked — every workload × both
//!    schedulers must produce runs bit-identical to a build that never
//!    had the fault model: the always-wrapped `FaultyBackend` is pure
//!    delegation and draws zero fault RNG while disabled.
//! 2. **Chaos is deterministic.** An enabled fault model is a pure
//!    function of (seed, config): running twice is bit-identical,
//!    under both schedulers, fault summary included.
//! 3. **Recovery completes the quota.** A chaos run with the recovery
//!    policy on reaches the same submission quota as the fault-free
//!    control; with recovery off every fault is abandoned on the spot.
//! 4. **Degradation has a floor.** When the fault model retires every
//!    lane, the run aborts loudly rather than scheduling into a dead
//!    platform.

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::test_support as ts;
use gpu_kernel_scientist::workload;

/// A chaos config hot enough to inject on tiny budgets while keeping
/// lane churn survivable (all-retired is a deliberate panic — see the
/// degradation test).
fn chaos(mut cfg: RunConfig) -> RunConfig {
    cfg.faults.enabled = true;
    cfg.faults.transient = 0.20;
    cfg.faults.straggler = 0.10;
    cfg.faults.corrupt = 0.10;
    cfg.faults.lane_death = 0.0;
    cfg.faults.backoff_base_s = 5.0;
    cfg.faults.quarantine_after = 10; // keep every lane in service
    cfg
}

#[test]
fn disabled_faults_are_bit_identical_for_every_workload_and_scheduler() {
    // the control parses a `[faults]` TOML section with every rate
    // cranked but `enabled = false`: the section must be inert
    let toml = "[faults]\nenabled = false\ntransient = 0.9\nstraggler = 0.9\n\
                corrupt = 0.9\nlane_death = 0.5\nrecovery = false\nmax_retries = 1\n";
    for w in workload::registry() {
        let name = w.name();
        for pipeline in [false, true] {
            let base = {
                let mut cfg = ts::tiny_run_config(13, 22).with_workload(name);
                cfg.eval_parallelism = if pipeline { 3 } else { 1 };
                cfg.pipeline = pipeline;
                cfg
            };
            let knobbed = {
                let parsed = RunConfig::from_toml(toml).expect("faults section parses");
                assert!(!parsed.faults.enabled && parsed.faults.transient == 0.9);
                let mut cfg = parsed.with_seed(13).with_budget(22).with_workload(name);
                cfg.eval_parallelism = base.eval_parallelism;
                cfg.pipeline = pipeline;
                cfg
            };
            let (run_a, out_a) = ts::run_scientist(base);
            let (run_b, out_b) = ts::run_scientist(knobbed);
            let tag = format!("{name} pipeline={pipeline}");
            assert_eq!(ts::trajectory(&run_a), ts::trajectory(&run_b), "{tag}");
            assert_eq!(out_a.best_id, out_b.best_id, "{tag}");
            assert_eq!(out_a.best_geomean_us, out_b.best_geomean_us, "{tag}");
            assert_eq!(out_a.submissions, out_b.submissions, "{tag}");
            assert_eq!(out_a.wall_clock_s, out_b.wall_clock_s, "{tag}");
            // the fault layer never came up: no state, no summary, no
            // scheduler recovery counters
            assert!(run_a.platform.fault_state().is_none(), "{tag}");
            assert!(run_b.platform.fault_state().is_none(), "{tag}");
            assert!(out_a.faults.is_none() && out_b.faults.is_none(), "{tag}");
            assert_eq!(out_a.pipeline.fault_retries, 0, "{tag}");
            assert_eq!(out_a.pipeline.fault_abandoned, 0, "{tag}");
        }
    }
}

#[test]
fn chaos_runs_are_reproducible_per_scheduler() {
    for pipeline in [false, true] {
        let run_once = || {
            let mut cfg = chaos(ts::tiny_run_config(31, 28));
            cfg.pipeline = pipeline;
            cfg.eval_parallelism = if pipeline { 3 } else { 2 };
            let (run, o) = ts::run_scientist(cfg);
            (ts::trajectory(&run), o.best_id, o.best_geomean_us, o.faults)
        };
        let a = run_once();
        assert_eq!(a, run_once(), "chaos pipeline={pipeline}");
        let summary = a.3.expect("chaos run carries fault state");
        assert!(
            summary.stats.injected() > 0,
            "pipeline={pipeline}: chaos never bit: {summary:?}"
        );
    }
}

#[test]
fn recovery_completes_the_quota_despite_chaos() {
    for pipeline in [false, true] {
        let mk = |faulty: bool| {
            let mut cfg = ts::tiny_run_config(47, 26);
            if faulty {
                cfg = chaos(cfg);
            }
            cfg.pipeline = pipeline;
            cfg.eval_parallelism = if pipeline { 3 } else { 2 };
            cfg
        };
        let (_, clean) = ts::run_scientist(mk(false));
        let (run, out) = ts::run_scientist(mk(true));
        let tag = format!("pipeline={pipeline}");
        // chaos costs retries, not quota: the run still commits the
        // full submission budget the fault-free control reaches
        assert_eq!(out.submissions, clean.submissions, "{tag}");
        let summary = out.faults.expect("chaos run carries fault state");
        assert!(summary.retries > 0, "{tag}: recovery never retried");
        assert_eq!(summary.retired_lanes, 0, "{tag}: no deaths configured");
        // the ledger accounts for every attempt, fault-class included
        assert_eq!(out.submissions as usize, run.population.len(), "{tag}");
    }
}

#[test]
fn no_recovery_abandons_every_fault_on_the_spot() {
    let mut cfg = chaos(ts::tiny_run_config(53, 24));
    cfg.faults.recovery = false;
    let (_, out) = ts::run_scientist(cfg);
    let summary = out.faults.expect("chaos run carries fault state");
    assert!(summary.stats.injected() > 0, "chaos never bit: {summary:?}");
    assert_eq!(summary.retries, 0, "recovery off must never retry");
    assert_eq!(
        summary.abandoned,
        summary.stats.injected(),
        "every injection abandons exactly once"
    );
    // and the recovery-side lane policy is off with it
    assert_eq!(summary.stats.quarantines, 0);
    assert_eq!(summary.stats.readmissions, 0);
}

#[test]
#[should_panic(expected = "evaluation lanes retired")]
fn retiring_every_lane_aborts_loudly() {
    // certain death on every dispatch: the first two dispatches retire
    // both lanes, and the next lane pick must abort the run rather
    // than schedule into a dead platform
    let mut cfg = ts::tiny_run_config(3, 20);
    cfg.eval_parallelism = 2;
    cfg.faults.enabled = true;
    cfg.faults.transient = 0.0;
    cfg.faults.straggler = 0.0;
    cfg.faults.corrupt = 0.0;
    cfg.faults.lane_death = 1.0;
    let _ = ts::run_scientist(cfg);
}
