//! Property-based tests on coordinator invariants.
//!
//! Offline build: no proptest crate, so properties are checked with an
//! in-tree generator (seeded `Rng`) over many random cases — same
//! spirit: random genomes/edits/populations, invariant assertions.

use gpu_kernel_scientist::agents::{Designer, SurrogateLlm};
use gpu_kernel_scientist::genome::{
    edit::{self, GenomeEdit},
    seeds, KernelGenome,
};
use gpu_kernel_scientist::gpu::{occupancy, MI300};
use gpu_kernel_scientist::metrics::geomean;
use gpu_kernel_scientist::rng::Rng;
use gpu_kernel_scientist::sim;
use gpu_kernel_scientist::test_support::{random_genome, random_valid_genome};
use gpu_kernel_scientist::workload::{GemmConfig, Workload};

const CASES: usize = 300;

fn random_config(rng: &mut Rng) -> GemmConfig {
    let dims = [512u32, 1024, 2048, 4096, 6144, 8192];
    GemmConfig::new(
        dims[rng.below(dims.len())],
        dims[rng.below(4)],
        dims[rng.below(dims.len())],
    )
}

#[test]
fn prop_valid_genomes_always_time_positive_finite() {
    let mut rng = Rng::seed_from_u64(100);
    let mut checked = 0;
    for _ in 0..CASES {
        let g = random_genome(&mut rng);
        if g.validate().is_err() {
            continue;
        }
        let cfg = random_config(&mut rng);
        let t = sim::estimate(&MI300, &g, &cfg).expect("valid genome must time");
        assert!(t.total_us.is_finite() && t.total_us > 0.0, "{g:?} {cfg}");
        assert!(t.compute_us > 0.0 && t.mem_us >= 0.0 && t.writeback_us > 0.0);
        assert!(t.grid_utilization > 0.0 && t.grid_utilization <= 1.0);
        checked += 1;
    }
    assert!(checked > CASES / 4, "too few valid cases: {checked}");
}

#[test]
fn prop_estimate_is_pure() {
    let mut rng = Rng::seed_from_u64(101);
    for _ in 0..CASES {
        let g = random_genome(&mut rng);
        if g.validate().is_err() {
            continue;
        }
        let cfg = random_config(&mut rng);
        assert_eq!(
            sim::estimate(&MI300, &g, &cfg),
            sim::estimate(&MI300, &g, &cfg)
        );
    }
}

#[test]
fn prop_timing_monotone_in_problem_size() {
    // growing any one dimension (same genome) never speeds the kernel
    // up by more than the noise-free model's tail-quantization wiggle
    let mut rng = Rng::seed_from_u64(102);
    for _ in 0..CASES {
        let g = random_genome(&mut rng);
        if g.validate().is_err() {
            continue;
        }
        let cfg = random_config(&mut rng);
        let big = GemmConfig::new(cfg.m * 2, cfg.k, cfg.n);
        let t1 = sim::estimate(&MI300, &g, &cfg).unwrap().total_us;
        let t2 = sim::estimate(&MI300, &g, &big).unwrap().total_us;
        assert!(
            t2 > t1 * 0.95,
            "{g:?}: m {}->{} went {t1} -> {t2}",
            cfg.m,
            big.m
        );
    }
}

#[test]
fn prop_edits_preserve_representability() {
    // every edit application keeps all fields inside the candidate sets
    let mut rng = Rng::seed_from_u64(103);
    for _ in 0..CASES {
        let mut g = seeds::mfma_seed();
        for _ in 0..12 {
            GenomeEdit::random(&mut rng).apply(&mut g);
        }
        // all block values from the candidate lattice
        for v in [g.block_m, g.block_n, g.block_k] {
            assert!([16, 32, 64, 128, 256].contains(&v), "{v}");
        }
        assert!([1, 2, 4, 8].contains(&g.unroll_k));
        assert!([1, 2, 4, 8, 16].contains(&g.vector_width));
        assert!([1, 2, 4, 8].contains(&g.waves_per_block));
        assert!(g.lds_pad <= 8);
    }
}

#[test]
fn prop_valid_neighbors_are_valid_and_single_axis() {
    let mut rng = Rng::seed_from_u64(104);
    for _ in 0..60 {
        let g = random_genome(&mut rng);
        if g.validate().is_err() {
            continue;
        }
        for (e, child) in edit::valid_neighbors(&g) {
            assert!(child.validate().is_ok());
            // applying the edit to the parent reproduces the child
            let again = edit::apply_edits(&g, &[e]);
            assert_eq!(again, child);
        }
    }
}

#[test]
fn prop_occupancy_bounded() {
    let mut rng = Rng::seed_from_u64(105);
    for _ in 0..CASES {
        let g = random_genome(&mut rng);
        if g.validate().is_err() {
            continue;
        }
        let occ = occupancy::occupancy(&MI300, &g);
        assert!(occ.waves_per_cu >= 1 || occ.workgroups_per_cu == 0);
        assert!(occ.waves_per_cu <= MI300.wave_slots_per_cu);
        assert!(occ.workgroups_per_cu <= 16);
    }
}

#[test]
fn prop_geomean_between_min_max() {
    let mut rng = Rng::seed_from_u64(106);
    for _ in 0..CASES {
        let n = 1 + rng.below(12);
        let xs: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 1e6)).collect();
        let g = geomean(&xs);
        let lo = xs.iter().cloned().fold(f64::MAX, f64::min);
        let hi = xs.iter().cloned().fold(f64::MIN, f64::max);
        assert!(g >= lo * 0.999999 && g <= hi * 1.000001);
    }
}

#[test]
fn prop_designer_choice_always_distinct_and_bounded() {
    use gpu_kernel_scientist::agents::knowledge::KnowledgeBase;
    use gpu_kernel_scientist::population::Population;
    use gpu_kernel_scientist::workload::FEEDBACK_CONFIGS;
    let mut rng = Rng::seed_from_u64(107);
    let pop = Population::new(FEEDBACK_CONFIGS.to_vec());
    let kb = KnowledgeBase::full();
    let designer = Designer::default();
    for i in 0..60 {
        let g = random_genome(&mut rng);
        if g.validate().is_err() {
            continue;
        }
        let mut llm = SurrogateLlm::with_seed(i);
        let out = designer.design("00001", &g, &pop, &kb, &mut llm, None);
        assert!(out.plans.len() <= 5);
        assert!(out.avenues.len() <= 10);
        let chosen = designer.choose(&out.plans, &mut llm);
        assert!(chosen.len() <= 3);
        let mut d = chosen.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), chosen.len(), "duplicate chosen indices");
        for i in chosen {
            assert!(i < out.plans.len());
        }
    }
}

#[test]
fn prop_writer_output_always_reported() {
    use gpu_kernel_scientist::agents::{ExperimentPlan, Writer};
    use gpu_kernel_scientist::agents::knowledge::Avenue;
    let mut rng = Rng::seed_from_u64(108);
    let writer = Writer::new();
    for i in 0..CASES {
        let base = {
            let g = random_genome(&mut rng);
            if g.validate().is_err() {
                continue;
            }
            g
        };
        let reference = seeds::human_oracle();
        let rubric: Vec<GenomeEdit> =
            (0..1 + rng.below(3)).map(|_| GenomeEdit::random(&mut rng)).collect();
        let plan = ExperimentPlan {
            avenue: Avenue::TileSizeTuning,
            description: "prop".into(),
            rubric_text: rubric.iter().map(|e| e.describe()).collect(),
            rubric,
            performance: (1.0, 10.0),
            innovation: 50,
        };
        let mut llm = SurrogateLlm::with_seed(i as u64);
        let out = writer.write(&base, &reference, &plan, &mut llm);
        // every rubric line is accounted for: applied or skipped
        assert_eq!(
            out.applied
                .iter()
                .filter(|a| !a.starts_with("adopted from reference"))
                .count()
                + out.skipped.len(),
            plan.rubric.len()
        );
        // writer reports always mention the experiment
        assert!(out.report.contains("Experiment:"));
    }
}

#[test]
fn prop_fingerprint_stable_under_clone_and_serialize_roundtrip() {
    // the eval cache keys on the fingerprint, so it must survive every
    // way a genome travels: clone, and JSON persist/parse round-trip
    let mut rng = Rng::seed_from_u64(120);
    for _ in 0..CASES {
        let g = random_genome(&mut rng);
        let fp = g.fingerprint();
        assert_eq!(g.clone().fingerprint(), fp);
        let json = g.to_json().to_string();
        let back = KernelGenome::from_json(
            &gpu_kernel_scientist::util::json::parse(&json).expect("parse"),
        )
        .expect("genome round-trip");
        assert_eq!(back.fingerprint(), fp, "{g:?}");
        assert_eq!(back, g);
    }
}

#[test]
fn prop_cache_hit_returns_the_recomputed_outcome() {
    // on a noiseless platform, serving a genome from the cache must
    // equal evaluating it again from scratch, bit for bit
    use gpu_kernel_scientist::eval::{EvalPlatform, PlatformConfig};
    use gpu_kernel_scientist::sim::SimBackend;
    let mut rng = Rng::seed_from_u64(121);
    for case in 0..40u64 {
        let g = random_valid_genome(&mut rng);
        let platform = |cache: bool| {
            EvalPlatform::new(
                SimBackend::new(case).with_noise(0.0),
                PlatformConfig {
                    cache_results: cache,
                    ..Default::default()
                },
            )
        };
        let mut cached = platform(true);
        let first = cached.submit_batch(std::slice::from_ref(&g));
        let hit = cached.submit_batch(std::slice::from_ref(&g));
        assert!(!first[0].cached && hit[0].cached);
        assert_eq!(hit[0].outcome, first[0].outcome, "cache hit == recorded");
        // true recompute: same backend seed, cache disabled
        let mut raw = platform(false);
        let r1 = raw.submit_batch(std::slice::from_ref(&g));
        let r2 = raw.submit_batch(std::slice::from_ref(&g));
        assert_eq!(r1[0].outcome, r2[0].outcome, "noiseless recompute is exact");
        assert_eq!(hit[0].outcome, r1[0].outcome, "cache hit == recompute");
    }
}

#[test]
fn prop_cache_stats_account_for_every_batch_submission() {
    // hits + misses == total genomes pushed through the batch path
    // (in-batch duplicates and repeats across batches included)
    use gpu_kernel_scientist::eval::{EvalPlatform, PlatformConfig};
    use gpu_kernel_scientist::sim::SimBackend;
    let mut rng = Rng::seed_from_u64(122);
    for case in 0..20u64 {
        let mut platform =
            EvalPlatform::new(SimBackend::new(case), PlatformConfig::default());
        let mut pool: Vec<KernelGenome> = Vec::new();
        while pool.len() < 4 {
            let g = random_valid_genome(&mut rng);
            if !pool.iter().any(|p| p.fingerprint() == g.fingerprint()) {
                pool.push(g);
            }
        }
        let mut submitted = 0u64;
        for _ in 0..4 {
            let batch: Vec<KernelGenome> = (0..1 + rng.below(6))
                .map(|_| pool[rng.below(pool.len())].clone())
                .collect();
            submitted += batch.len() as u64;
            let results = platform.submit_batch(&batch);
            assert_eq!(results.len(), batch.len(), "no quota: nothing truncated");
            let (hits, misses) = platform.cache_stats();
            assert_eq!(
                hits + misses,
                submitted,
                "case {case}: every batch entry is exactly one counted lookup"
            );
        }
        // quota truncation drops entries *uncounted*: the invariant is
        // over processed entries (results returned), not attempts
        let mut quota = EvalPlatform::new(
            SimBackend::new(case),
            PlatformConfig {
                submission_quota: Some(1),
                ..Default::default()
            },
        );
        let results = quota.submit_batch(&pool);
        assert_eq!(results.len(), 1);
        let (h, m) = quota.cache_stats();
        assert_eq!(h + m, 1, "case {case}: truncated entries stay uncounted");
        // and uncached platforms count nothing
        let mut raw = EvalPlatform::new(
            SimBackend::new(case),
            PlatformConfig {
                cache_results: false,
                ..Default::default()
            },
        );
        raw.submit_batch(&pool);
        assert_eq!(raw.cache_stats(), (0, 0));
    }
}

#[test]
fn prop_ledger_entry_and_genome_json_roundtrip_lossless() {
    // the run-store journal (DESIGN.md §9) makes serialized ledger
    // entries a real input path: to_json → emit → parse → from_json
    // must be lossless for randomized genomes and unicode-heavy
    // rationale strings — including non-BMP scalars (the surrogate-pair
    // parser fix) and JSON-hostile characters
    use gpu_kernel_scientist::population::{EvalOutcome, Individual};
    use gpu_kernel_scientist::store::{ExperimentRecord, JournalRecord, PlanRecord};
    use gpu_kernel_scientist::util::json;

    const POOL: &[char] = &[
        'a', 'Z', '0', ' ', '"', '\\', '/', '\n', '\r', '\t', '\u{1}', '\u{1f}', 'é', 'ß',
        '世', '界', '→', '\u{2028}', '😀', '🚀', '\u{1d4b3}', '\u{10ffff}', '\u{fffd}',
    ];
    let mut rng = Rng::seed_from_u64(130);
    let random_text = |rng: &mut Rng| -> String {
        (0..rng.below(40)).map(|_| *rng.choose(POOL)).collect()
    };
    for case in 0..CASES {
        let outcome = match rng.below(3) {
            0 => EvalOutcome::Timings((0..6).map(|_| rng.range_f64(1.0, 9e4)).collect()),
            1 => EvalOutcome::CompileFailure(random_text(&mut rng)),
            _ => EvalOutcome::IncorrectResult(random_text(&mut rng)),
        };
        let cached = rng.chance(0.3);
        let record = JournalRecord::Exp(ExperimentRecord {
            individual: Individual {
                id: format!("{case:05}"),
                parents: (0..rng.below(3)).map(|p| format!("{p:05}")).collect(),
                genome: random_genome(&mut rng),
                experiment: random_text(&mut rng),
                report: random_text(&mut rng),
                outcome,
            },
            submitted_at: rng.below(500) as u64 + 1,
            submission_index: if cached { None } else { Some(case as u64) },
            cached,
            lane: if cached { None } else { Some(rng.below(8) as u32) },
            completed_at_s: if cached {
                None
            } else {
                Some(rng.range_f64(90.0, 9e5))
            },
            plan: if rng.chance(0.5) {
                Some(rng.below(64))
            } else {
                None
            },
            screened: rng.chance(0.5),
            profile: if rng.chance(0.5) {
                use gpu_kernel_scientist::sim::{profile, ProfileReport};
                let costs = [
                    rng.range_f64(0.0, 5e4),
                    rng.range_f64(0.0, 5e4),
                    rng.range_f64(0.0, 5e4),
                    rng.range_f64(0.0, 5e4),
                    rng.range_f64(0.0, 5e4),
                ];
                let (bottleneck, secondary) = profile::classify(&costs);
                Some(ProfileReport {
                    mem_us: costs[0],
                    compute_us: costs[1],
                    lds_us: costs[2],
                    occupancy_us: costs[3],
                    launch_us: costs[4],
                    bottleneck,
                    secondary,
                })
            } else {
                None
            },
            federated: rng.chance(0.2),
            lint: if rng.chance(0.3) {
                (0..1 + rng.below(3))
                    .map(|_| random_text(&mut rng))
                    .collect()
            } else {
                Vec::new()
            },
        });
        let emitted = record.to_json().to_string();
        let back = JournalRecord::from_json(&json::parse(&emitted).expect("parse"))
            .expect("ledger entry round-trip");
        // deterministic emission (ordered keys) makes re-emission a
        // full structural equality check
        assert_eq!(back.to_json().to_string(), emitted, "case {case}");
        let (JournalRecord::Exp(a), JournalRecord::Exp(b)) = (&record, &back) else {
            panic!("tag changed in round-trip");
        };
        assert_eq!(a.individual, b.individual, "case {case}");
        assert_eq!(a.individual.genome.fingerprint(), b.individual.genome.fingerprint());

        // plan records carry the selector rationale — the most
        // unicode-heavy free text in the ledger
        let plan = JournalRecord::Plan(PlanRecord {
            iteration: case,
            log_pos: case,
            base_id: "00007".into(),
            reference_id: "00003".into(),
            policy: None,
            rationale: random_text(&mut rng),
            avenues: (0..rng.below(4)).map(|_| random_text(&mut rng)).collect(),
            chosen: (0..rng.below(3)).map(|_| random_text(&mut rng)).collect(),
            screened: rng.below(4) as u64,
            linted: rng.below(3) as u64,
        });
        let emitted = plan.to_json().to_string();
        let back = JournalRecord::from_json(&json::parse(&emitted).expect("parse plan"))
            .expect("plan round-trip");
        assert_eq!(back.to_json().to_string(), emitted, "plan case {case}");
    }
}

#[test]
fn prop_surrogate_escaped_text_parses_to_the_same_scalars() {
    // any non-BMP scalar written as a \uXXXX\uXXXX pair must parse to
    // the same string as the raw UTF-8 form (RFC 8259 §7)
    use gpu_kernel_scientist::util::json;
    let mut rng = Rng::seed_from_u64(131);
    for _ in 0..CASES {
        let mut raw = String::from("x");
        let mut escaped = String::from("\"x");
        for _ in 0..1 + rng.below(12) {
            // random supplementary-plane scalar
            let cp = 0x10000 + (rng.next_u64() % (0x10FFFF - 0x10000 + 1)) as u32;
            let Some(c) = char::from_u32(cp) else { continue };
            raw.push(c);
            let v = cp - 0x10000;
            let hi = 0xD800 + (v >> 10);
            let lo = 0xDC00 + (v & 0x3FF);
            escaped.push_str(&format!("\\u{hi:04X}\\u{lo:04X}"));
        }
        escaped.push('"');
        let parsed = json::parse(&escaped).expect("escaped pair parses");
        assert_eq!(parsed.as_str(), Some(raw.as_str()));
        // and the raw form round-trips through our emitter
        let emitted = json::Json::Str(raw.clone()).to_string();
        assert_eq!(json::parse(&emitted).unwrap().as_str(), Some(raw.as_str()));
    }
}

#[test]
fn prop_u64_and_string_fingerprints_agree() {
    // the hot paths key on the u64 content hash; the string form stays
    // for display/persistence. The two must agree on identity: equal
    // strings ⟺ equal hashes, over random pairs, exact clones, and
    // single-edit neighbors (the adversarial near-miss case)
    let mut rng = Rng::seed_from_u64(140);
    let mut genomes: Vec<KernelGenome> = Vec::new();
    for _ in 0..80 {
        let g = random_genome(&mut rng);
        genomes.push(g.clone());
        if rng.chance(0.3) {
            genomes.push(g.clone()); // exact duplicate pair
        }
        for (_, n) in edit::valid_neighbors(&g).into_iter().take(3) {
            genomes.push(n);
        }
    }
    for _ in 0..2000 {
        let a = &genomes[rng.below(genomes.len())];
        let b = &genomes[rng.below(genomes.len())];
        assert_eq!(
            a.fingerprint() == b.fingerprint(),
            a.fingerprint_hash() == b.fingerprint_hash(),
            "hash/string disagreement:\n{a:?}\n{b:?}"
        );
        // and both track genome equality exactly
        assert_eq!(a.fingerprint() == b.fingerprint(), a == b);
    }
}

#[test]
fn prop_lint_error_iff_validate_or_admits_rejects() {
    // the analyzer's Error set must equal the platform's static reject
    // set — `validate` ∪ `admits` — on arbitrary edit-walk genomes,
    // against every registered workload (DESIGN.md §13). Both
    // directions: an error implies a rejection and vice versa, and the
    // first error code matches the rejecting verdict's stable code.
    use gpu_kernel_scientist::analysis::{self, Severity};
    let mut rng = Rng::seed_from_u64(0x11_47);
    let registry = gpu_kernel_scientist::workload::registry();
    for case in 0..CASES {
        let g = random_genome(&mut rng);
        let w = &registry[case % registry.len()];
        let diags = analysis::lint(&g, &MI300, w.as_ref());
        let rejected = g.validate().is_err() || w.admits(&g).is_err();
        assert_eq!(
            analysis::has_error(&diags),
            rejected,
            "case {case} on {}: lint/reject disagreement for {g:?}",
            w.name()
        );
        match g.validate() {
            Err(inv) => assert_eq!(
                diags.first().map(|d| d.code.as_str()),
                Some(inv.code()),
                "case {case}: first error must carry the validate code"
            ),
            Ok(()) if w.admits(&g).is_err() => assert_eq!(
                diags.first().map(|d| d.code.as_str()),
                Some(analysis::ADMITS_CODE),
                "case {case} on {}: admits rejection miscoded",
                w.name()
            ),
            Ok(()) => assert!(
                diags.iter().all(|d| d.severity == Severity::Warn),
                "case {case}: error diagnostic on an accepted genome"
            ),
        }
    }
}

#[test]
fn prop_lint_is_deterministic_and_roundtrips_json() {
    // diagnostics are a pure function of (genome, arch, workload), in
    // a stable order, and survive the journal's JSON wire format
    // losslessly — streamed emission byte-identical to the tree form
    use gpu_kernel_scientist::analysis::{self, Diagnostic};
    use gpu_kernel_scientist::util::json;
    let mut rng = Rng::seed_from_u64(0x11_48);
    let registry = gpu_kernel_scientist::workload::registry();
    for case in 0..CASES {
        let g = random_genome(&mut rng);
        let w = &registry[case % registry.len()];
        let diags = analysis::lint(&g, &MI300, w.as_ref());
        assert_eq!(
            diags,
            analysis::lint(&g, &MI300, w.as_ref()),
            "case {case}: lint is not pure"
        );
        for d in &diags {
            let tree = d.to_json();
            let back = Diagnostic::from_json(&tree).expect("diag roundtrip");
            assert_eq!(&back, d, "case {case}: lossy diagnostic roundtrip");
            let mut streamed = String::new();
            d.write_json(&mut streamed);
            assert_eq!(streamed, tree.to_string(), "case {case}: stream drifted");
            let reparsed = json::parse(&streamed).expect("diag json parses");
            assert_eq!(Diagnostic::from_json(&reparsed).unwrap(), *d);
        }
    }
}

/// The scan-based archive the indexed [`Population`] replaced: every
/// query recomputed from the raw member list, exactly as the old
/// implementation did (first-minimum wins; stable sort order on ties;
/// specialist scan in insertion order with first-beating-config
/// weights). The reference for the observational-equivalence property.
mod naive_archive {
    use gpu_kernel_scientist::population::Individual;

    pub fn by_id<'a>(members: &'a [Individual], id: &str) -> Option<&'a Individual> {
        members.iter().find(|m| m.id == id)
    }

    pub fn successful(members: &[Individual]) -> Vec<&Individual> {
        members.iter().filter(|m| m.outcome.is_success()).collect()
    }

    pub fn best(members: &[Individual]) -> Option<&Individual> {
        successful(members)
            .into_iter()
            .min_by(|a, b| a.score().unwrap().total_cmp(&b.score().unwrap()))
    }

    pub fn leaderboard(members: &[Individual]) -> Vec<String> {
        let mut ok = successful(members);
        ok.sort_by(|a, b| a.score().unwrap().total_cmp(&b.score().unwrap()));
        ok.into_iter().map(|m| m.id.clone()).collect()
    }

    pub fn config_winners(members: &[Individual], n: usize) -> Vec<Option<String>> {
        let mut winners: Vec<Option<(String, f64)>> = vec![None; n];
        for m in successful(members) {
            if let Some(ts) = m.outcome.timings() {
                for (i, &t) in ts.iter().enumerate().take(n) {
                    if winners[i].as_ref().map(|(_, best)| t < *best).unwrap_or(true) {
                        winners[i] = Some((m.id.clone(), t));
                    }
                }
            }
        }
        winners.into_iter().map(|w| w.map(|(id, _)| id)).collect()
    }

    pub fn ancestors<'a>(members: &'a [Individual], id: &str) -> Vec<&'a Individual> {
        let mut out: Vec<&Individual> = Vec::new();
        let mut cur = by_id(members, id);
        while let Some(ind) = cur {
            if let Some(parent_id) = ind.parents.first() {
                cur = by_id(members, parent_id);
                if let Some(p) = cur {
                    if out.iter().any(|x| x.id == p.id) {
                        break; // cycle guard (old code shape)
                    }
                    out.push(p);
                }
            } else {
                break;
            }
        }
        out
    }

    pub fn common_ancestor<'a>(
        members: &'a [Individual],
        a: &str,
        b: &str,
    ) -> Option<&'a Individual> {
        let anc_a = ancestors(members, a);
        let anc_b: std::collections::HashSet<&str> =
            ancestors(members, b).iter().map(|m| m.id.as_str()).collect();
        anc_a.into_iter().find(|m| anc_b.contains(m.id.as_str()))
    }

    pub fn find_duplicate<'a>(
        members: &'a [Individual],
        g: &gpu_kernel_scientist::genome::KernelGenome,
    ) -> Option<&'a Individual> {
        let fp = g.fingerprint();
        members.iter().find(|m| m.genome.fingerprint() == fp)
    }

    /// The old selector's per-config-specialist scan: members (in
    /// insertion order) beating `base` on >= 1 config, tagged with the
    /// first beating config index.
    pub fn config_beaters<'a>(
        members: &'a [Individual],
        base: &Individual,
    ) -> Vec<(usize, &'a Individual)> {
        let mut out = Vec::new();
        let Some(base_ts) = base.outcome.timings() else {
            return out;
        };
        'members: for m in successful(members) {
            if m.id == base.id {
                continue;
            }
            if let Some(ts) = m.outcome.timings() {
                for (i, (&t, &bt)) in ts.iter().zip(base_ts.iter()).enumerate() {
                    if t < bt {
                        out.push((i, m));
                        continue 'members;
                    }
                }
            }
        }
        out
    }
}

#[test]
fn prop_indexed_archive_matches_naive_reference() {
    use gpu_kernel_scientist::population::{EvalOutcome, Individual, Population};
    use gpu_kernel_scientist::workload::FEEDBACK_CONFIGS;
    let mut rng = Rng::seed_from_u64(141);
    for case in 0..60 {
        let nc = FEEDBACK_CONFIGS.len();
        let n = 2 + rng.below(50);
        let mut members: Vec<Individual> = Vec::new();
        let mut pop = Population::new(FEEDBACK_CONFIGS.to_vec());
        for i in 0..n {
            let id = format!("{:05}", i + 1);
            let parents = if i == 0 || rng.chance(0.2) {
                vec![]
            } else {
                // first parent always an earlier member; optional
                // second (reference) parent
                let mut ps = vec![format!("{:05}", 1 + rng.below(i))];
                if rng.chance(0.4) {
                    ps.push(format!("{:05}", 1 + rng.below(i)));
                }
                ps
            };
            // duplicate genomes on purpose: dedup tie-breaks matter
            let genome = if i > 0 && rng.chance(0.3) {
                members[rng.below(i)].genome.clone()
            } else {
                random_genome(&mut rng)
            };
            // quantized timings so exact score/timing ties are common
            let outcome = match rng.below(5) {
                0 => EvalOutcome::CompileFailure("nope".into()),
                1 => EvalOutcome::IncorrectResult("race".into()),
                _ => EvalOutcome::Timings(
                    (0..nc).map(|_| 50.0 * (1 + rng.below(6)) as f64).collect(),
                ),
            };
            let ind = Individual {
                id,
                parents,
                genome,
                experiment: format!("exp {i}"),
                report: String::new(),
                outcome,
            };
            members.push(ind.clone());
            pop.add(ind);
        }

        // point queries agree member-for-member
        assert_eq!(pop.best().map(|m| &m.id), naive_archive::best(&members).map(|m| &m.id));
        let lb: Vec<String> = pop.leaderboard_members().map(|m| m.id.clone()).collect();
        assert_eq!(lb, naive_archive::leaderboard(&members), "case {case}");
        let ok: Vec<&str> = pop.successful().iter().map(|m| m.id.as_str()).collect();
        let ok_naive: Vec<&str> =
            naive_archive::successful(&members).iter().map(|m| m.id.as_str()).collect();
        assert_eq!(ok, ok_naive);
        assert_eq!(pop.successful_count(), ok_naive.len());
        assert_eq!(
            pop.config_winners(),
            naive_archive::config_winners(&members, nc),
            "case {case}"
        );
        for m in &members {
            assert_eq!(
                pop.by_id(&m.id).map(|x| &x.id),
                naive_archive::by_id(&members, &m.id).map(|x| &x.id)
            );
            let anc: Vec<&str> =
                pop.ancestors(&m.id).iter().map(|x| x.id.as_str()).collect();
            let anc_naive: Vec<&str> = naive_archive::ancestors(&members, &m.id)
                .iter()
                .map(|x| x.id.as_str())
                .collect();
            assert_eq!(anc, anc_naive, "case {case} ancestors of {}", m.id);
            assert_eq!(
                pop.find_duplicate(&m.genome).map(|x| &x.id),
                naive_archive::find_duplicate(&members, &m.genome).map(|x| &x.id),
                "case {case} dup of {}",
                m.id
            );
            assert!(pop.contains_genome(m.genome.fingerprint_hash(), &m.genome));
        }
        assert_eq!(pop.by_id("99999").map(|m| &m.id), None);
        // a genome absent from the archive misses in both
        let novel = loop {
            let g = random_genome(&mut rng);
            if naive_archive::find_duplicate(&members, &g).is_none() {
                break g;
            }
        };
        assert!(pop.find_duplicate(&novel).is_none());
        assert!(!pop.contains_genome(novel.fingerprint_hash(), &novel));
        // pairwise lineage queries on sampled pairs
        for _ in 0..10 {
            let a = &members[rng.below(n)].id;
            let b = &members[rng.below(n)].id;
            assert_eq!(
                pop.common_ancestor(a, b).map(|m| &m.id),
                naive_archive::common_ancestor(&members, a, b).map(|m| &m.id),
                "case {case} common_ancestor({a}, {b})"
            );
        }
        // the specialist query agrees for the best member and a random
        // successful one (content, order, first-config attribution)
        let mut bases: Vec<&Individual> = Vec::new();
        if let Some(best) = pop.best() {
            bases.push(best);
        }
        if !ok.is_empty() {
            bases.push(pop.nth_successful(rng.below(ok.len())));
        }
        for base in bases {
            let got: Vec<(usize, &str)> = pop
                .config_beaters(base)
                .into_iter()
                .map(|(i, m)| (i, m.id.as_str()))
                .collect();
            let want: Vec<(usize, &str)> = naive_archive::config_beaters(&members, base)
                .into_iter()
                .map(|(i, m)| (i, m.id.as_str()))
                .collect();
            assert_eq!(got, want, "case {case} beaters of {}", base.id);
        }
    }
}

#[test]
fn prop_population_jsonl_roundtrip_random() {
    use gpu_kernel_scientist::population::{EvalOutcome, Individual, Population};
    use gpu_kernel_scientist::workload::FEEDBACK_CONFIGS;
    let mut rng = Rng::seed_from_u64(109);
    for case in 0..40 {
        let mut pop = Population::new(FEEDBACK_CONFIGS.to_vec());
        let n = 1 + rng.below(20);
        for i in 0..n {
            let id = format!("{:05}", i + 1);
            let parents = if i == 0 {
                vec![]
            } else {
                vec![format!("{:05}", 1 + rng.below(i))]
            };
            let outcome = match rng.below(3) {
                0 => EvalOutcome::Timings((0..6).map(|_| rng.range_f64(50.0, 9000.0)).collect()),
                1 => EvalOutcome::CompileFailure(format!("err \"quoted\" {case}")),
                _ => EvalOutcome::IncorrectResult("race\ncondition".into()),
            };
            pop.add(Individual {
                id,
                parents,
                genome: random_genome(&mut rng),
                experiment: format!("exp\t{i}"),
                report: "multi\nline".into(),
                outcome,
            });
        }
        let text = pop.to_jsonl();
        let back = Population::from_jsonl(&text, FEEDBACK_CONFIGS.to_vec()).unwrap();
        assert_eq!(back.len(), pop.len());
        for (a, b) in pop.members().iter().zip(back.members()) {
            assert_eq!(a, b);
        }
    }
}

#[test]
fn prop_profile_classification_matches_reference_recomputation() {
    // a ProfileReport is a pure function of the noiseless KernelTimings
    // (DESIGN.md §11): over randomized valid genomes, the classification
    // must equal an independent recomputation of the attribution from
    // the raw timing fields, and the report must survive JSON
    // round-trips losslessly (tree and streamed emitters byte-equal)
    use gpu_kernel_scientist::sim::{profile, Bottleneck, KernelTiming, ProfileReport};
    use gpu_kernel_scientist::util::json;
    use gpu_kernel_scientist::workload::FEEDBACK_CONFIGS;
    let mut rng = Rng::seed_from_u64(150);
    let mut checked = 0;
    for _ in 0..CASES {
        let g = random_genome(&mut rng);
        if g.validate().is_err() {
            continue;
        }
        let timings: Vec<KernelTiming> = FEEDBACK_CONFIGS
            .iter()
            .map(|c| sim::estimate(&MI300, &g, c).expect("valid genome must time"))
            .collect();
        let p = ProfileReport::from_timings(&timings);

        // independent reference: re-derive the five component sums
        // straight from the timing fields, then rank them by hand
        let mut sums = [0.0f64; 5];
        for t in &timings {
            let mem = t.mem_us + t.writeback_us;
            let compute = t.compute_us;
            let lds = t.compute_us * t.lds_pressure;
            let busy = mem + compute + lds;
            let occ = if t.grid_utilization > 0.0 {
                busy * (1.0 / t.grid_utilization - 1.0)
            } else {
                0.0
            };
            sums[0] += mem;
            sums[1] += compute;
            sums[2] += lds;
            sums[3] += occ;
            sums[4] += t.launch_us;
        }
        let report_sums = [p.mem_us, p.compute_us, p.lds_us, p.occupancy_us, p.launch_us];
        for (got, want) in report_sums.iter().zip(sums.iter()) {
            assert!(
                (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                "{g:?}: component sums diverged ({report_sums:?} vs {sums:?})"
            );
        }
        // primary: first maximum in Bottleneck::ALL order
        let mut best = 0;
        for i in 1..5 {
            if sums[i] > sums[best] {
                best = i;
            }
        }
        assert_eq!(p.bottleneck, Bottleneck::ALL[best], "{g:?}");
        // secondary: second-ranked component iff it clears the share floor
        let mut ranked: Vec<usize> = (0..5).collect();
        ranked.sort_by(|&a, &b| sums[b].total_cmp(&sums[a]));
        let total: f64 = sums.iter().sum();
        let want_secondary = if total > 0.0
            && sums[ranked[1]] >= profile::SECONDARY_SHARE * total
        {
            Some(Bottleneck::ALL[ranked[1]])
        } else {
            None
        };
        assert_eq!(p.secondary, want_secondary, "{g:?}");

        // JSON round-trip: tree emitter == streamed emitter, lossless
        let emitted = p.to_json().to_string();
        let mut streamed = String::new();
        p.write_json(&mut streamed);
        assert_eq!(streamed, emitted, "{g:?}");
        let back =
            ProfileReport::from_json(&json::parse(&emitted).expect("parse")).expect("round-trip");
        assert_eq!(back, p, "{g:?}");
        checked += 1;
    }
    assert!(checked > CASES / 4, "too few valid cases: {checked}");
}

#[test]
fn prop_screen_promotion_is_exactly_the_top_keep_fraction() {
    // randomized rungs with adversarial scores (None / NaN / inf mixed
    // with finite): the survivors are exactly the naive reference's top
    // keep-fraction by `f64::total_cmp` with submission-order ties,
    // returned in submission order; non-finite candidates are never
    // promoted and never panic the comparator
    use gpu_kernel_scientist::eval::{ScreenConfig, ScreenTier};
    use gpu_kernel_scientist::workload::default_workload;
    let mut rng = Rng::seed_from_u64(110);
    for case in 0..CASES {
        let n = 1 + rng.below(12);
        let keep = rng.range_f64(0.05, 1.0);
        let scores: Vec<Option<f64>> = (0..n)
            .map(|_| match rng.below(8) {
                0 => None,
                1 => Some(f64::NAN),
                2 => Some(f64::INFINITY),
                3 => Some(f64::NEG_INFINITY),
                // duplicates on purpose: the tie-break must matter
                4 => Some(50.0),
                _ => Some(rng.range_f64(1.0, 1000.0)),
            })
            .collect();
        let mut tier: ScreenTier<usize> = ScreenTier::new(
            ScreenConfig {
                rung: n as u32,
                keep_fraction: keep,
            },
            default_workload(),
        );
        let mut decided = None;
        for (i, s) in scores.iter().enumerate() {
            if let Some(out) = tier.push_scored(*s, i) {
                decided = Some(out);
            }
        }
        let out = decided.expect("a rung of n fills after n pushes");
        // conservation: every candidate decided exactly once
        assert_eq!(out.promoted.len() + out.rejected.len(), n, "case {case}");
        let stats = tier.stats();
        assert_eq!(stats.screened, n as u64, "case {case}");
        assert_eq!(stats.promoted + stats.rejected, stats.screened, "case {case}");
        assert_eq!(tier.pending(), 0, "case {case}");
        // naive reference: finite-scored candidates ranked by
        // (total_cmp score, submission seq), top clamp(ceil(keep*n), 1, n)
        let mut finite: Vec<usize> = (0..n)
            .filter(|&i| scores[i].is_some_and(f64::is_finite))
            .collect();
        finite.sort_by(|&a, &b| scores[a].unwrap().total_cmp(&scores[b].unwrap()).then(a.cmp(&b)));
        let keep_target = ((keep * n as f64).ceil() as usize).clamp(1, n);
        finite.truncate(keep_target);
        finite.sort_unstable(); // survivors return in submission order
        assert_eq!(
            out.promoted, finite,
            "case {case} keep={keep} scores={scores:?}"
        );
        for &i in &out.promoted {
            assert!(
                scores[i].is_some_and(f64::is_finite),
                "case {case}: non-finite candidate {i} promoted"
            );
        }
    }
}

#[test]
fn prop_screen_conservation_holds_at_every_instant() {
    // screened == promoted + rejected + pending after every push, and
    // a final flush decides everything: screened == promoted + rejected
    use gpu_kernel_scientist::eval::{ScreenConfig, ScreenTier};
    use gpu_kernel_scientist::workload::default_workload;
    let mut rng = Rng::seed_from_u64(111);
    for case in 0..60 {
        let rung = 1 + rng.below(5) as u32;
        let keep = rng.range_f64(0.1, 1.0);
        let total = 1 + rng.below(40);
        let mut tier: ScreenTier<usize> = ScreenTier::new(
            ScreenConfig {
                rung,
                keep_fraction: keep,
            },
            default_workload(),
        );
        for i in 0..total {
            let s = if rng.chance(0.2) {
                None
            } else {
                Some(rng.range_f64(1.0, 500.0))
            };
            let _ = tier.push_scored(s, i);
            let st = tier.stats();
            assert_eq!(
                st.screened,
                st.promoted + st.rejected + tier.pending() as u64,
                "case {case} after push {i}"
            );
        }
        let _ = tier.flush();
        let st = tier.stats();
        assert_eq!(tier.pending(), 0, "case {case}");
        assert_eq!(st.screened, total as u64, "case {case}");
        assert_eq!(st.promoted + st.rejected, st.screened, "case {case}");
    }
}

#[test]
fn prop_screen_score_matches_the_cost_model_geomean() {
    // the screen score is the pure feedback-suite geomean of the
    // analytic cost model: recomputing it is exact (the resume path
    // relies on this), invalid/inadmissible genomes score None, and a
    // Some score is always finite and positive
    use gpu_kernel_scientist::eval::{ScreenConfig, ScreenTier};
    use gpu_kernel_scientist::workload::{default_workload, Workload};
    let mut rng = Rng::seed_from_u64(112);
    let w = default_workload();
    let tier: ScreenTier<usize> = ScreenTier::new(ScreenConfig::default(), w.clone());
    let mut scored = 0usize;
    for _ in 0..CASES {
        let g = random_genome(&mut rng);
        let score = tier.score(&g);
        assert_eq!(score, tier.score(&g), "scoring must be pure");
        if g.validate().is_err() || w.admits(&g).is_err() {
            assert_eq!(score, None, "{g:?}");
            continue;
        }
        let Some(s) = score else {
            // score may only be refused if the cost model itself failed
            // or produced a non-finite/non-positive timing somewhere
            let bad = w.feedback_suite().configs.iter().any(|c| {
                !w.estimate(&MI300, &g, c)
                    .is_ok_and(|t| t.total_us.is_finite() && t.total_us > 0.0)
            });
            assert!(bad, "score None but the cost model succeeded: {g:?}");
            continue;
        };
        assert!(s.is_finite() && s > 0.0, "{g:?}");
        let timings: Vec<f64> = w
            .feedback_suite()
            .configs
            .iter()
            .map(|c| w.estimate(&MI300, &g, c).unwrap().total_us)
            .collect();
        let expected = geomean(&timings);
        assert!((s - expected).abs() <= 1e-9 * expected, "{s} vs {expected}");
        scored += 1;
    }
    assert!(scored > CASES / 4, "too few scoreable cases: {scored}");
}

#[test]
fn prop_fault_accounting_reconciles_journal_stats_and_summary() {
    // DESIGN.md §14: a chaos run's books must balance three ways —
    // the journal's typed fault records, the platform's committed
    // FaultStats, and the scheduler's retry/abandon counters all
    // describe the same events. Over several seeds x both schedulers:
    //   * each telemetry kind's journal count equals its stats counter
    //     ("suspect" also counts as corrupted: the corrupted timing IS
    //     the suspect one, so corrupt + suspect records == corrupted);
    //   * lane-health records (quarantine/readmit/retire) match;
    //   * "retry" records == summary.retries, "abandon" == abandoned;
    //   * every injected fault resolves to exactly one decision on its
    //     own completion — a retry or an abandon that still carries
    //     the completion's submission index (queue-drain abandons at
    //     quota exhaustion carry none: their failed attempt already
    //     resolved as a retry) — and ledgers exactly one fault-class
    //     experiment entry.
    use gpu_kernel_scientist::config::RunConfig;
    use gpu_kernel_scientist::scientist::ScientistRun;
    use gpu_kernel_scientist::store::{self, journal, JournalRecord};
    use gpu_kernel_scientist::test_support::scratch_dir;

    let mut injected_total = 0u64;
    let mut lane_events_total = 0u64;
    for pipeline in [false, true] {
        for seed in 0..3u64 {
            let dir = scratch_dir("prop-faults");
            let mut cfg = RunConfig::default()
                .with_seed(9100 + seed)
                .with_budget(24)
                .with_parallelism(3)
                .with_pipeline(pipeline);
            cfg.store_dir = Some(dir.display().to_string());
            // hot enough to exercise retries and lane churn, cool
            // enough that three lanes never all retire (all-retired
            // is a deliberate panic, not an Err)
            cfg.faults.enabled = true;
            cfg.faults.transient = 0.15;
            cfg.faults.straggler = 0.10;
            cfg.faults.corrupt = 0.10;
            cfg.faults.lane_death = 0.01;
            cfg.faults.backoff_base_s = 5.0;
            cfg.faults.quarantine_after = 3;
            cfg.faults.probation_s = 60.0;
            let mut run = ScientistRun::new(cfg).expect("setup");
            let out = run.run_to_completion().expect("chaos run");
            let summary = out.faults.expect("fault layer ran");

            let text =
                std::fs::read_to_string(dir.join(store::JOURNAL_FILE)).unwrap();
            let (records, torn) = journal::parse_journal(&text).unwrap();
            assert!(!torn);
            let mut kinds: std::collections::HashMap<&str, u64> =
                std::collections::HashMap::new();
            let mut abandons_on_completion = 0u64;
            let mut fault_exps = 0u64;
            for r in &records {
                match r {
                    JournalRecord::Fault(f) => {
                        *kinds.entry(f.kind.as_str()).or_insert(0) += 1;
                        if f.kind == "abandon" && f.submission_index.is_some() {
                            abandons_on_completion += 1;
                        }
                    }
                    JournalRecord::Exp(e) => {
                        if e.individual.outcome.is_fault() {
                            fault_exps += 1;
                        }
                    }
                    _ => {}
                }
            }
            let n = |k: &str| kinds.get(k).copied().unwrap_or(0);
            let label = format!("pipeline={pipeline} seed={seed}");
            let s = &summary.stats;
            assert_eq!(n("transient"), s.transients, "{label}");
            assert_eq!(n("lane_death"), s.lane_deaths, "{label}");
            assert_eq!(n("straggler_timeout"), s.straggler_timeouts, "{label}");
            assert_eq!(n("straggler"), s.stragglers, "{label}");
            assert_eq!(n("suspect"), s.suspects, "{label}");
            assert_eq!(n("corrupt") + n("suspect"), s.corrupted, "{label}");
            assert_eq!(n("quarantine"), s.quarantines, "{label}");
            assert_eq!(n("readmit"), s.readmissions, "{label}");
            assert_eq!(n("retire"), s.retirements, "{label}");
            assert_eq!(n("retry"), summary.retries, "{label}");
            assert_eq!(n("abandon"), summary.abandoned, "{label}");
            assert_eq!(
                n("retry") + abandons_on_completion,
                s.injected(),
                "{label}: every injection resolves exactly once"
            );
            assert_eq!(
                fault_exps,
                s.injected(),
                "{label}: every injection ledgers one fault-class entry"
            );
            injected_total += s.injected();
            lane_events_total += s.quarantines + s.readmissions + s.retirements;
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
    // the property is vacuous unless the chaos actually bites
    assert!(injected_total > 0, "no faults injected across any case");
    assert!(lane_events_total > 0, "no lane-health churn across any case");
}
