//! Property-based tests on coordinator invariants.
//!
//! Offline build: no proptest crate, so properties are checked with an
//! in-tree generator (seeded `Rng`) over many random cases — same
//! spirit: random genomes/edits/populations, invariant assertions.

use gpu_kernel_scientist::agents::{Designer, SurrogateLlm};
use gpu_kernel_scientist::genome::{
    edit::{self, GenomeEdit},
    seeds, KernelGenome,
};
use gpu_kernel_scientist::gpu::{occupancy, MI300};
use gpu_kernel_scientist::metrics::geomean;
use gpu_kernel_scientist::rng::Rng;
use gpu_kernel_scientist::sim;
use gpu_kernel_scientist::test_support::{random_genome, random_valid_genome};
use gpu_kernel_scientist::workload::GemmConfig;

const CASES: usize = 300;

fn random_config(rng: &mut Rng) -> GemmConfig {
    let dims = [512u32, 1024, 2048, 4096, 6144, 8192];
    GemmConfig::new(
        dims[rng.below(dims.len())],
        dims[rng.below(4)],
        dims[rng.below(dims.len())],
    )
}

#[test]
fn prop_valid_genomes_always_time_positive_finite() {
    let mut rng = Rng::seed_from_u64(100);
    let mut checked = 0;
    for _ in 0..CASES {
        let g = random_genome(&mut rng);
        if g.validate().is_err() {
            continue;
        }
        let cfg = random_config(&mut rng);
        let t = sim::estimate(&MI300, &g, &cfg).expect("valid genome must time");
        assert!(t.total_us.is_finite() && t.total_us > 0.0, "{g:?} {cfg}");
        assert!(t.compute_us > 0.0 && t.mem_us >= 0.0 && t.writeback_us > 0.0);
        assert!(t.grid_utilization > 0.0 && t.grid_utilization <= 1.0);
        checked += 1;
    }
    assert!(checked > CASES / 4, "too few valid cases: {checked}");
}

#[test]
fn prop_estimate_is_pure() {
    let mut rng = Rng::seed_from_u64(101);
    for _ in 0..CASES {
        let g = random_genome(&mut rng);
        if g.validate().is_err() {
            continue;
        }
        let cfg = random_config(&mut rng);
        assert_eq!(
            sim::estimate(&MI300, &g, &cfg),
            sim::estimate(&MI300, &g, &cfg)
        );
    }
}

#[test]
fn prop_timing_monotone_in_problem_size() {
    // growing any one dimension (same genome) never speeds the kernel
    // up by more than the noise-free model's tail-quantization wiggle
    let mut rng = Rng::seed_from_u64(102);
    for _ in 0..CASES {
        let g = random_genome(&mut rng);
        if g.validate().is_err() {
            continue;
        }
        let cfg = random_config(&mut rng);
        let big = GemmConfig::new(cfg.m * 2, cfg.k, cfg.n);
        let t1 = sim::estimate(&MI300, &g, &cfg).unwrap().total_us;
        let t2 = sim::estimate(&MI300, &g, &big).unwrap().total_us;
        assert!(
            t2 > t1 * 0.95,
            "{g:?}: m {}->{} went {t1} -> {t2}",
            cfg.m,
            big.m
        );
    }
}

#[test]
fn prop_edits_preserve_representability() {
    // every edit application keeps all fields inside the candidate sets
    let mut rng = Rng::seed_from_u64(103);
    for _ in 0..CASES {
        let mut g = seeds::mfma_seed();
        for _ in 0..12 {
            GenomeEdit::random(&mut rng).apply(&mut g);
        }
        // all block values from the candidate lattice
        for v in [g.block_m, g.block_n, g.block_k] {
            assert!([16, 32, 64, 128, 256].contains(&v), "{v}");
        }
        assert!([1, 2, 4, 8].contains(&g.unroll_k));
        assert!([1, 2, 4, 8, 16].contains(&g.vector_width));
        assert!([1, 2, 4, 8].contains(&g.waves_per_block));
        assert!(g.lds_pad <= 8);
    }
}

#[test]
fn prop_valid_neighbors_are_valid_and_single_axis() {
    let mut rng = Rng::seed_from_u64(104);
    for _ in 0..60 {
        let g = random_genome(&mut rng);
        if g.validate().is_err() {
            continue;
        }
        for (e, child) in edit::valid_neighbors(&g) {
            assert!(child.validate().is_ok());
            // applying the edit to the parent reproduces the child
            let again = edit::apply_edits(&g, &[e]);
            assert_eq!(again, child);
        }
    }
}

#[test]
fn prop_occupancy_bounded() {
    let mut rng = Rng::seed_from_u64(105);
    for _ in 0..CASES {
        let g = random_genome(&mut rng);
        if g.validate().is_err() {
            continue;
        }
        let occ = occupancy::occupancy(&MI300, &g);
        assert!(occ.waves_per_cu >= 1 || occ.workgroups_per_cu == 0);
        assert!(occ.waves_per_cu <= MI300.wave_slots_per_cu);
        assert!(occ.workgroups_per_cu <= 16);
    }
}

#[test]
fn prop_geomean_between_min_max() {
    let mut rng = Rng::seed_from_u64(106);
    for _ in 0..CASES {
        let n = 1 + rng.below(12);
        let xs: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 1e6)).collect();
        let g = geomean(&xs);
        let lo = xs.iter().cloned().fold(f64::MAX, f64::min);
        let hi = xs.iter().cloned().fold(f64::MIN, f64::max);
        assert!(g >= lo * 0.999999 && g <= hi * 1.000001);
    }
}

#[test]
fn prop_designer_choice_always_distinct_and_bounded() {
    use gpu_kernel_scientist::agents::knowledge::KnowledgeBase;
    use gpu_kernel_scientist::population::Population;
    use gpu_kernel_scientist::workload::FEEDBACK_CONFIGS;
    let mut rng = Rng::seed_from_u64(107);
    let pop = Population::new(FEEDBACK_CONFIGS.to_vec());
    let kb = KnowledgeBase::full();
    let designer = Designer::default();
    for i in 0..60 {
        let g = random_genome(&mut rng);
        if g.validate().is_err() {
            continue;
        }
        let mut llm = SurrogateLlm::with_seed(i);
        let out = designer.design("00001", &g, &pop, &kb, &mut llm);
        assert!(out.plans.len() <= 5);
        assert!(out.avenues.len() <= 10);
        let chosen = designer.choose(&out.plans, &mut llm);
        assert!(chosen.len() <= 3);
        let mut d = chosen.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), chosen.len(), "duplicate chosen indices");
        for i in chosen {
            assert!(i < out.plans.len());
        }
    }
}

#[test]
fn prop_writer_output_always_reported() {
    use gpu_kernel_scientist::agents::{ExperimentPlan, Writer};
    use gpu_kernel_scientist::agents::knowledge::Avenue;
    let mut rng = Rng::seed_from_u64(108);
    let writer = Writer::new();
    for i in 0..CASES {
        let base = {
            let g = random_genome(&mut rng);
            if g.validate().is_err() {
                continue;
            }
            g
        };
        let reference = seeds::human_oracle();
        let rubric: Vec<GenomeEdit> =
            (0..1 + rng.below(3)).map(|_| GenomeEdit::random(&mut rng)).collect();
        let plan = ExperimentPlan {
            avenue: Avenue::TileSizeTuning,
            description: "prop".into(),
            rubric_text: rubric.iter().map(|e| e.describe()).collect(),
            rubric,
            performance: (1.0, 10.0),
            innovation: 50,
        };
        let mut llm = SurrogateLlm::with_seed(i as u64);
        let out = writer.write(&base, &reference, &plan, &mut llm);
        // every rubric line is accounted for: applied or skipped
        assert_eq!(
            out.applied
                .iter()
                .filter(|a| !a.starts_with("adopted from reference"))
                .count()
                + out.skipped.len(),
            plan.rubric.len()
        );
        // writer reports always mention the experiment
        assert!(out.report.contains("Experiment:"));
    }
}

#[test]
fn prop_fingerprint_stable_under_clone_and_serialize_roundtrip() {
    // the eval cache keys on the fingerprint, so it must survive every
    // way a genome travels: clone, and JSON persist/parse round-trip
    let mut rng = Rng::seed_from_u64(120);
    for _ in 0..CASES {
        let g = random_genome(&mut rng);
        let fp = g.fingerprint();
        assert_eq!(g.clone().fingerprint(), fp);
        let json = g.to_json().to_string();
        let back = KernelGenome::from_json(
            &gpu_kernel_scientist::util::json::parse(&json).expect("parse"),
        )
        .expect("genome round-trip");
        assert_eq!(back.fingerprint(), fp, "{g:?}");
        assert_eq!(back, g);
    }
}

#[test]
fn prop_cache_hit_returns_the_recomputed_outcome() {
    // on a noiseless platform, serving a genome from the cache must
    // equal evaluating it again from scratch, bit for bit
    use gpu_kernel_scientist::eval::{EvalPlatform, PlatformConfig};
    use gpu_kernel_scientist::sim::SimBackend;
    let mut rng = Rng::seed_from_u64(121);
    for case in 0..40u64 {
        let g = random_valid_genome(&mut rng);
        let platform = |cache: bool| {
            EvalPlatform::new(
                SimBackend::new(case).with_noise(0.0),
                PlatformConfig {
                    cache_results: cache,
                    ..Default::default()
                },
            )
        };
        let mut cached = platform(true);
        let first = cached.submit_batch(std::slice::from_ref(&g));
        let hit = cached.submit_batch(std::slice::from_ref(&g));
        assert!(!first[0].cached && hit[0].cached);
        assert_eq!(hit[0].outcome, first[0].outcome, "cache hit == recorded");
        // true recompute: same backend seed, cache disabled
        let mut raw = platform(false);
        let r1 = raw.submit_batch(std::slice::from_ref(&g));
        let r2 = raw.submit_batch(std::slice::from_ref(&g));
        assert_eq!(r1[0].outcome, r2[0].outcome, "noiseless recompute is exact");
        assert_eq!(hit[0].outcome, r1[0].outcome, "cache hit == recompute");
    }
}

#[test]
fn prop_cache_stats_account_for_every_batch_submission() {
    // hits + misses == total genomes pushed through the batch path
    // (in-batch duplicates and repeats across batches included)
    use gpu_kernel_scientist::eval::{EvalPlatform, PlatformConfig};
    use gpu_kernel_scientist::sim::SimBackend;
    let mut rng = Rng::seed_from_u64(122);
    for case in 0..20u64 {
        let mut platform =
            EvalPlatform::new(SimBackend::new(case), PlatformConfig::default());
        let mut pool: Vec<KernelGenome> = Vec::new();
        while pool.len() < 4 {
            let g = random_valid_genome(&mut rng);
            if !pool.iter().any(|p| p.fingerprint() == g.fingerprint()) {
                pool.push(g);
            }
        }
        let mut submitted = 0u64;
        for _ in 0..4 {
            let batch: Vec<KernelGenome> = (0..1 + rng.below(6))
                .map(|_| pool[rng.below(pool.len())].clone())
                .collect();
            submitted += batch.len() as u64;
            let results = platform.submit_batch(&batch);
            assert_eq!(results.len(), batch.len(), "no quota: nothing truncated");
            let (hits, misses) = platform.cache_stats();
            assert_eq!(
                hits + misses,
                submitted,
                "case {case}: every batch entry is exactly one counted lookup"
            );
        }
        // quota truncation drops entries *uncounted*: the invariant is
        // over processed entries (results returned), not attempts
        let mut quota = EvalPlatform::new(
            SimBackend::new(case),
            PlatformConfig {
                submission_quota: Some(1),
                ..Default::default()
            },
        );
        let results = quota.submit_batch(&pool);
        assert_eq!(results.len(), 1);
        let (h, m) = quota.cache_stats();
        assert_eq!(h + m, 1, "case {case}: truncated entries stay uncounted");
        // and uncached platforms count nothing
        let mut raw = EvalPlatform::new(
            SimBackend::new(case),
            PlatformConfig {
                cache_results: false,
                ..Default::default()
            },
        );
        raw.submit_batch(&pool);
        assert_eq!(raw.cache_stats(), (0, 0));
    }
}

#[test]
fn prop_population_jsonl_roundtrip_random() {
    use gpu_kernel_scientist::population::{EvalOutcome, Individual, Population};
    use gpu_kernel_scientist::workload::FEEDBACK_CONFIGS;
    let mut rng = Rng::seed_from_u64(109);
    for case in 0..40 {
        let mut pop = Population::new(FEEDBACK_CONFIGS.to_vec());
        let n = 1 + rng.below(20);
        for i in 0..n {
            let id = format!("{:05}", i + 1);
            let parents = if i == 0 {
                vec![]
            } else {
                vec![format!("{:05}", 1 + rng.below(i))]
            };
            let outcome = match rng.below(3) {
                0 => EvalOutcome::Timings((0..6).map(|_| rng.range_f64(50.0, 9000.0)).collect()),
                1 => EvalOutcome::CompileFailure(format!("err \"quoted\" {case}")),
                _ => EvalOutcome::IncorrectResult("race\ncondition".into()),
            };
            pop.add(Individual {
                id,
                parents,
                genome: random_genome(&mut rng),
                experiment: format!("exp\t{i}"),
                report: "multi\nline".into(),
                outcome,
            });
        }
        let text = pop.to_jsonl();
        let back = Population::from_jsonl(&text, FEEDBACK_CONFIGS.to_vec()).unwrap();
        assert_eq!(back.len(), pop.len());
        for (a, b) in pop.members().iter().zip(back.members()) {
            assert_eq!(a, b);
        }
    }
}
