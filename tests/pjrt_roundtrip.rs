//! PJRT integration: load the AOT artifact catalog, verify kernel
//! variants against the compiled reference path, time them, and drive
//! the scientist loop over real compiled kernels.
//!
//! These tests need `make artifacts` to have run; they skip (pass
//! trivially, with a note) when the catalog is absent so `cargo test`
//! works on a fresh checkout.

use std::path::Path;

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::eval::{EvalBackend, EvalPlatform, PlatformConfig};
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::runtime::PjrtBackend;
use gpu_kernel_scientist::workload::GemmConfig;

fn open_backend() -> Option<PjrtBackend> {
    let dir = Path::new("artifacts");
    if !dir.join("catalog.json").exists() {
        eprintln!("SKIP: artifacts/catalog.json missing (run `make artifacts`)");
        return None;
    }
    let mut b = PjrtBackend::open(dir).expect("backend open");
    b.inner_reps = 1;
    Some(b)
}

const CFG: GemmConfig = GemmConfig::new(256, 256, 256);

#[test]
fn catalog_covers_testbed_shapes() {
    let Some(backend) = open_backend() else { return };
    let shapes = backend.shapes();
    assert!(shapes.contains(&CFG), "shapes: {shapes:?}");
    assert!(backend.catalog().reference_for(&CFG).is_some());
    assert!(backend.catalog().variants_for(&CFG).len() >= 5);
}

#[test]
fn default_variant_verifies_and_times() {
    let Some(mut backend) = open_backend() else { return };
    // the python default GemmVariant(128,128,64,fused,scratch,ki)
    let name = "g128x128x64_fs_sc_ki_m256k256n256";
    backend.verify(name, &CFG).expect("numerics match reference");
    let us = backend.time_entry(name, &CFG).expect("timing");
    assert!(us > 0.0 && us < 60_000_000.0);
}

#[test]
fn naive_structure_slower_than_evolved_structure() {
    // The paper's seed ordering holds on the real backend too: the
    // naive-translation variant (tiny tiles, k-outermost, no scratch
    // accumulator) is far slower than the evolved structure.
    let Some(mut backend) = open_backend() else { return };
    let naive = backend
        .time_entry("g32x32x32_us_oa_ko_m256k256n256", &CFG)
        .expect("naive timing");
    let evolved = backend
        .time_entry("g128x128x64_fs_sc_ki_m256k256n256", &CFG)
        .expect("evolved timing");
    assert!(
        naive > 2.0 * evolved,
        "naive {naive:.0} us vs evolved {evolved:.0} us"
    );
}

#[test]
fn genome_projection_times_through_eval_backend_trait() {
    let Some(mut backend) = open_backend() else { return };
    let g = seeds::human_oracle(); // projects to a large-tile variant
    let us = EvalBackend::measure(&mut backend, &g, &CFG).expect("measure");
    assert!(us > 0.0);
    // check() runs the correctness gate on the smallest shape
    EvalBackend::check(&mut backend, &g).expect("check");
}

#[test]
fn scientist_loop_runs_over_pjrt() {
    let Some(backend) = open_backend() else { return };
    let platform = EvalPlatform::new(
        backend,
        PlatformConfig {
            reps_per_config: 1,
            parallelism: 1,
            submission_quota: Some(8),
            ..Default::default()
        },
    )
    .with_feedback_suite(BenchmarkSuite {
        name: "pjrt-primary".into(),
        configs: vec![CFG],
    });
    let cfg = RunConfig::default().with_seed(3).with_budget(8);
    let mut run = ScientistRun::with_platform(cfg, platform).expect("setup");
    let outcome = run.run_to_completion().expect("run");
    assert!(outcome.submissions <= 8);
    assert!(outcome.best_geomean_us.is_finite());
    assert!(outcome.best_geomean_us > 0.0);
    // the loop produced at least one non-seed individual
    assert!(run.population.len() > 3);
}
