//! End-to-end integration tests over the simulated evaluation platform:
//! whole scientist runs, persistence, and the Table-1 shape assertions.

use gpu_kernel_scientist::config::RunConfig;
use gpu_kernel_scientist::gpu::MI300;
use gpu_kernel_scientist::population::Population;
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::sim::calibration::leaderboard_geomean;
use gpu_kernel_scientist::test_support::{run_scientist, tiny_run_config};

fn run_with(seed: u64, budget: u64) -> (ScientistRun<FaultyBackend<SimBackend>>, RunOutcome) {
    run_scientist(tiny_run_config(seed, budget))
}

#[test]
fn full_run_reproduces_table1_shape() {
    let (_, outcome) = run_with(0, 120);
    let lib = leaderboard_geomean(&MI300, &seeds::pytorch_reference());
    let naive = leaderboard_geomean(&MI300, &seeds::naive_hip());
    let oracle = leaderboard_geomean(&MI300, &seeds::human_oracle());
    let this_work = outcome.leaderboard_us.expect("leaderboard score");
    // Table 1 ordering: naive > pytorch > this work > human oracle
    assert!(naive > lib);
    assert!(
        this_work < lib,
        "scientist ({this_work:.0} us) must beat the library ({lib:.0} us)"
    );
    assert!(
        oracle < this_work * 1.10,
        "oracle ({oracle:.0} us) should stay ahead of or match the loop ({this_work:.0} us)"
    );
    // rough factor: the loop lands well below 1x library but the paper's
    // system does NOT reach the human-expert bound
    assert!(lib / this_work >= 1.2, "expected >=1.2x over library");
}

#[test]
fn population_ledger_is_consistent() {
    let (run, outcome) = run_with(1, 60);
    let pop = &run.population;
    assert_eq!(outcome.submissions as usize, pop.len());
    // ids are sequential and parents resolve
    for (i, m) in pop.members().iter().enumerate() {
        assert_eq!(m.id, format!("{:05}", i + 1));
        for p in &m.parents {
            assert!(pop.by_id(p).is_some(), "dangling parent {p}");
        }
    }
    // the first three are the paper's seeds
    assert!(pop.by_id("00001").unwrap().experiment.contains("pytorch-reference"));
    assert!(pop.by_id("00002").unwrap().experiment.contains("naive-hip"));
    assert!(pop.by_id("00003").unwrap().experiment.contains("mfma-seed"));
    // every non-seed has both a base and a reference parent
    for m in pop.members().iter().skip(3) {
        assert_eq!(m.parents.len(), 2, "{} parents: {:?}", m.id, m.parents);
    }
}

#[test]
fn population_persists_and_resumes() {
    let (run, _) = run_with(2, 40);
    let path = std::env::temp_dir().join(format!(
        "gks_pop_{}_{}.jsonl",
        std::process::id(),
        2
    ));
    run.population.save(&path).expect("save");
    let loaded =
        Population::load(&path, run.population.feedback_configs.clone()).expect("load");
    assert_eq!(loaded.len(), run.population.len());
    assert_eq!(
        loaded.best().map(|b| b.id.clone()),
        run.population.best().map(|b| b.id.clone())
    );
    // lineage queries still work after the round-trip
    let best_id = loaded.best().unwrap().id.clone();
    assert!(!loaded.ancestors(&best_id).is_empty());
    std::fs::remove_file(&path).ok();
}

#[test]
fn submission_log_matches_population() {
    let (run, _) = run_with(3, 30);
    let log = run.platform.log();
    assert_eq!(log.len(), run.population.len());
    for (rec, member) in log.iter().zip(run.population.members()) {
        assert_eq!(rec.outcome, member.outcome);
    }
    // simulated wall clock advanced strictly sequentially
    let mut last = 0.0;
    for rec in log {
        assert!(rec.completed_at_s > last);
        last = rec.completed_at_s;
    }
}

#[test]
fn failed_submissions_recorded_not_fatal() {
    // with a hot/high-infidelity LLM some submissions fail; the loop
    // must keep going and still improve
    let mut cfg = tiny_run_config(4, 80);
    cfg.llm.rubric_infidelity = 0.3;
    cfg.llm.temperature = 2.0;
    let mut run = ScientistRun::new(cfg).expect("setup");
    let outcome = run.run_to_completion().expect("run");
    assert!(outcome.best_geomean_us.is_finite());
    // likely at least one incorrect/compile-failure individual exists
    let failures = run
        .population
        .members()
        .iter()
        .filter(|m| !m.outcome.is_success())
        .count();
    // don't hard-require failures (seeded), but the ledger must account
    // for every submission either way
    assert_eq!(
        run.platform.submissions() as usize,
        run.population.len(),
        "failures={failures}"
    );
}

#[test]
fn knowledge_ablation_degrades_result() {
    let full = {
        let (_, o) = run_with(5, 80);
        o.best_geomean_us
    };
    let minimal = {
        let mut cfg = tiny_run_config(5, 80);
        cfg.knowledge = KnowledgeProfile::Minimal;
        let mut run = ScientistRun::new(cfg).expect("setup");
        run.run_to_completion().expect("run").best_geomean_us
    };
    assert!(
        full < minimal,
        "full knowledge ({full:.0} us) should beat minimal ({minimal:.0} us)"
    );
}

#[test]
fn parallel_lanes_cut_wall_clock_not_quality() {
    let (_, seq) = run_with(6, 60);
    let mut cfg = tiny_run_config(6, 60);
    cfg.eval_parallelism = 3;
    let mut run = ScientistRun::new(cfg).expect("setup");
    let par = run.run_to_completion().expect("run");
    assert!(par.wall_clock_s < seq.wall_clock_s * 0.5);
}

#[test]
fn bootstrap_probing_derives_findings_and_still_wins() {
    let mut cfg = tiny_run_config(7, 90);
    cfg.bootstrap_probing = true;
    let mut run = ScientistRun::new(cfg).expect("setup");
    // the three probes + three seeds are in the ledger
    assert_eq!(run.population.len(), 6);
    assert!(run
        .population
        .by_id("00001")
        .unwrap()
        .experiment
        .contains("bootstrap probe"));
    // the negative probe is recorded as an incorrect result
    let probe3 = run.population.by_id("00003").unwrap();
    assert!(!probe3.outcome.is_success(), "{:?}", probe3.outcome);
    let outcome = run.run_to_completion().expect("run");
    let lib = leaderboard_geomean(&MI300, &seeds::pytorch_reference());
    assert!(outcome.leaderboard_us.unwrap() < lib);
}

#[test]
fn config_files_in_repo_parse() {
    for f in [
        "configs/paper.toml",
        "configs/bootstrap.toml",
        "configs/campaign.toml",
    ] {
        let text = std::fs::read_to_string(f).expect(f);
        let cfg = RunConfig::from_toml(&text).expect(f);
        assert_eq!(cfg.max_submissions, 120);
        assert!(gpu_kernel_scientist::workload::lookup(&cfg.workload).is_some());
    }
}

#[test]
fn e2e_runs_on_every_registered_workload_with_consistent_ledgers() {
    // the workload-generic twin of the fp8 assertions above: seeds
    // first, sequential ids, two-parent children, log == ledger
    for w in gpu_kernel_scientist::workload::registry() {
        let (run, outcome) =
            run_scientist(tiny_run_config(11, 40).with_workload(w.name()));
        let pop = &run.population;
        assert_eq!(outcome.submissions as usize, pop.len(), "{}", w.name());
        let seeds = w.starting_population();
        for (i, (seed_name, _)) in seeds.iter().enumerate() {
            let member = pop.by_id(&format!("{:05}", i + 1)).unwrap();
            assert!(
                member.experiment.contains(seed_name),
                "{}: seed row {i} is {}",
                w.name(),
                member.experiment
            );
        }
        for m in pop.members().iter().skip(seeds.len()) {
            assert_eq!(m.parents.len(), 2, "{}: {}", w.name(), m.id);
        }
    }
}

#[test]
fn lineage_tree_of_real_run_is_consistent() {
    use gpu_kernel_scientist::report::lineage;
    let (run, _) = run_with(8, 50);
    let tree = lineage::render_tree(&run.population);
    // every member id appears exactly once in the tree
    for m in run.population.members() {
        assert_eq!(
            tree.matches(&m.id).count(),
            1,
            "{} appears wrong number of times",
            m.id
        );
    }
    let d = lineage::diversity(&run.population);
    assert!(d.axes_explored >= 3);
    assert!(d.max_depth >= 1);
}
