//! Integration tests for the multi-lane evaluation executor and the
//! eval-result cache (DESIGN.md §3):
//!
//! * parallelism = 1 reproduces the exact sequential submission path —
//!   same outcomes, same wall clock, same population trajectory;
//! * parallelism = N preserves submission-order accounting (log
//!   indices, lane clocks) and stays deterministic per seed;
//! * the genome-hash cache returns identical `EvalOutcome`s without
//!   consuming submission quota or platform time.

use gpu_kernel_scientist::eval::{EvalPlatform, PlatformConfig};
use gpu_kernel_scientist::prelude::*;
use gpu_kernel_scientist::test_support::{
    distinct_genomes, run_scientist, tiny_run_config, trajectory,
};

#[test]
fn single_lane_batch_is_bit_identical_to_sequential_submits() {
    let jobs = distinct_genomes(8);
    let mut seq = EvalPlatform::new(SimBackend::new(9), PlatformConfig::default());
    let expected: Vec<_> = jobs.iter().map(|g| seq.submit(g)).collect();

    let mut bat = EvalPlatform::new(SimBackend::new(9), PlatformConfig::default());
    let results = bat.submit_batch(&jobs);

    assert_eq!(results.len(), jobs.len());
    for (i, (r, e)) in results.iter().zip(&expected).enumerate() {
        assert_eq!(&r.outcome, e, "outcome {i} must match the sequential path");
        assert_eq!(r.submission_index, Some(i as u64));
        assert!(!r.cached);
    }
    assert_eq!(bat.wall_clock_s(), seq.wall_clock_s());
    assert_eq!(bat.submissions(), seq.submissions());
    let seq_times: Vec<f64> = seq.log().iter().map(|r| r.completed_at_s).collect();
    let bat_times: Vec<f64> = bat.log().iter().map(|r| r.completed_at_s).collect();
    assert_eq!(seq_times, bat_times);
}

#[test]
fn scientist_trajectory_at_parallelism_one_is_deterministic_and_cache_neutral() {
    let run_once = |eval_cache: bool| {
        let mut cfg = tiny_run_config(13, 40);
        cfg.eval_cache = eval_cache;
        let (run, outcome) = run_scientist(cfg);
        (outcome, trajectory(&run))
    };
    let (o1, t1) = run_once(true);
    let (o2, t2) = run_once(true);
    let (o3, t3) = run_once(false);
    assert_eq!(t1, t2, "same seed, same sequential trajectory");
    assert_eq!(o1.best_id, o2.best_id);
    assert_eq!(o1.best_geomean_us, o2.best_geomean_us);
    // the scientist dedups before submitting, so the cache must be
    // invisible to the trajectory
    assert_eq!(t1, t3, "cache on/off must not change the trajectory");
    assert_eq!(o1.best_geomean_us, o3.best_geomean_us);
}

#[test]
fn parallel_batch_preserves_submission_order_accounting() {
    let jobs = distinct_genomes(9);
    let mut p = EvalPlatform::new(
        SimBackend::new(21),
        PlatformConfig {
            parallelism: 3,
            ..Default::default()
        },
    );
    let results = p.submit_batch(&jobs);
    assert_eq!(results.len(), 9);
    for (i, r) in results.iter().enumerate() {
        assert_eq!(
            r.submission_index,
            Some(i as u64),
            "log order == submission order"
        );
        // earliest-free-lane accounting with equal 90 s costs: jobs
        // 0..2 finish at 90 s, 3..5 at 180 s, 6..8 at 270 s
        let expected = 90.0 * ((i / 3) + 1) as f64;
        assert!(
            (r.completed_at_s - expected).abs() < 1e-9,
            "job {i}: completed at {} expected {expected}",
            r.completed_at_s
        );
    }
    assert_eq!(p.submissions(), 9);
    assert!((p.wall_clock_s() - 270.0).abs() < 1e-9);
    // the platform log is ordered by submission index, not by which
    // lane thread finished first
    for (i, rec) in p.log().iter().enumerate() {
        assert_eq!(rec.index, i as u64);
    }
}

#[test]
fn parallel_batches_are_deterministic_per_seed() {
    let jobs = distinct_genomes(10);
    let run = || {
        let mut p = EvalPlatform::new(
            SimBackend::new(33),
            PlatformConfig {
                parallelism: 4,
                ..Default::default()
            },
        );
        p.submit_batch(&jobs)
            .into_iter()
            .map(|r| r.outcome)
            .collect::<Vec<_>>()
    };
    assert_eq!(run(), run(), "static lane partition is schedule-independent");
}

#[test]
fn cache_returns_identical_outcomes_without_consuming_quota() {
    let jobs = distinct_genomes(3);
    let mut p = EvalPlatform::new(
        SimBackend::new(5),
        PlatformConfig {
            submission_quota: Some(3),
            ..Default::default()
        },
    );
    let first = p.submit_batch(&jobs[..2]);
    assert_eq!(p.submissions(), 2);
    let clock = p.wall_clock_s();

    // resubmit the same two (now cached) plus one new genome
    let mixed = vec![jobs[1].clone(), jobs[0].clone(), jobs[2].clone()];
    let second = p.submit_batch(&mixed);
    assert_eq!(second.len(), 3);
    assert!(second[0].cached && second[1].cached && !second[2].cached);
    assert_eq!(second[0].outcome, first[1].outcome, "identical EvalOutcome");
    assert_eq!(second[1].outcome, first[0].outcome, "identical EvalOutcome");
    assert_eq!(
        p.submissions(),
        3,
        "cache hits consume no submission quota"
    );
    assert_eq!(p.cache_stats().0, 2, "two counted cache hits");
    assert!(
        p.wall_clock_s() > clock,
        "only the uncached genome consumed platform time"
    );
    assert!((p.wall_clock_s() - clock - 90.0).abs() < 1e-9);
    // quota is now exhausted, but cached genomes can still be served
    assert!(p.quota_exhausted());
    let third = p.submit_batch(&jobs[..1]);
    assert_eq!(third.len(), 1);
    assert!(third[0].cached);
}

#[test]
fn multi_lane_scientist_run_is_reproducible() {
    let run = || {
        let mut cfg = tiny_run_config(4, 36);
        cfg.eval_parallelism = 3;
        let (_, o) = run_scientist(cfg);
        (o.best_id.clone(), o.best_geomean_us, o.submissions)
    };
    assert_eq!(run(), run());
}

#[test]
fn genetic_baseline_runs_through_the_batch_executor() {
    use gpu_kernel_scientist::baselines::{GeneticAlgorithm, Tuner};
    let mut p = EvalPlatform::new(
        SimBackend::new(8),
        PlatformConfig {
            parallelism: 3,
            submission_quota: Some(60),
            ..Default::default()
        },
    );
    let out = GeneticAlgorithm {
        seed: 8,
        ..Default::default()
    }
    .run(&mut p, 60);
    assert!(out.submissions <= 60);
    assert!(out.best_geomean_us.is_finite());
    // three lanes: the same submission count takes a third of the
    // simulated platform time (± one partially-filled round)
    let rounds = (out.submissions as f64 / 3.0).ceil();
    assert!(p.wall_clock_s() <= rounds * 90.0 + 1e-9);
}
