#!/usr/bin/env bash
# detlint — determinism source lint over rust/src/** (DESIGN.md §13).
#
# The simulator's trajectories must be a pure function of (seed,
# config). This script greps for the three source patterns that can
# silently break that property, using nothing beyond POSIX shell,
# grep, find, and awk — it runs anywhere the repo checks out, with no
# toolchain installed.
#
#   DL001  `partial_cmp` — NaN-unordered float comparison. Use
#          `total_cmp` (or an integer ordering key) so a NaN-poisoned
#          score cannot flip a sort.
#   DL002  wall-clock reads (`Instant::now`, `SystemTime`) outside the
#          sanctioned timing modules (util/timer.rs, util/bench.rs,
#          runtime/mod.rs). Wall time anywhere else can leak into a
#          trajectory.
#   DL003  iteration over a `HashMap`/`HashSet` — visit order depends
#          on the hasher and allocation history. Iterate an ordered
#          collection instead, or sort the collected result and
#          annotate the site.
#   DL004  `.unwrap()`/`.expect()` on an I/O Result in the durability
#          layers (store/, eval/, non-test code). A panic mid-write can
#          tear the journal a resume depends on; either propagate the
#          error or annotate the deliberate fail-stop sites so every
#          crash-on-I/O-error decision is visible in review.
#
# A finding is suppressed by ending the offending line with:
#     // detlint: allow(DLnnn)
# The annotation is deliberately per-line and per-code so every escape
# is visible in review next to the code it excuses.
#
# Exit status: 0 when clean, 1 when any unannotated finding remains.

set -u
cd "$(dirname "$0")/.."

SRC=rust/src
fail=0

report() { # code file:line message
    printf 'detlint: %s %s: %s\n' "$1" "$2" "$3"
    fail=1
}

# ---- DL001: partial_cmp ---------------------------------------------------
while IFS=: read -r file line text; do
    [ -z "${file:-}" ] && continue
    case $text in *"detlint: allow(DL001)"*) continue ;; esac
    report DL001 "$file:$line" "partial_cmp is NaN-unordered; use total_cmp or an ordering key"
done <<EOF
$(grep -rn --include='*.rs' 'partial_cmp' "$SRC" || true)
EOF

# ---- DL002: wall-clock reads outside the timing modules -------------------
while IFS=: read -r file line text; do
    [ -z "${file:-}" ] && continue
    case $file in
        "$SRC"/util/timer.rs | "$SRC"/util/bench.rs | "$SRC"/runtime/mod.rs) continue ;;
    esac
    case $text in *"detlint: allow(DL002)"*) continue ;; esac
    report DL002 "$file:$line" "wall-clock read outside util/timer.rs, util/bench.rs, runtime/mod.rs"
done <<EOF
$(grep -rn --include='*.rs' -E 'Instant::now|SystemTime' "$SRC" || true)
EOF

# ---- DL003: HashMap/HashSet iteration -------------------------------------
# Two-phase scan per file: collect every binding whose declaration names
# a HashMap or HashSet (let bindings with a hash type or `Hash*::new()`
# initializer, struct fields, fn params), then flag lines that iterate
# one of those names — `name.iter()/into_iter()/keys()/values()/drain()`,
# a continuation line `.into_iter()` whose previous line ends with the
# name (rustfmt splits long chains that way), or `for .. in &name`.
# Declarations precede uses in every scope we care about, so a single
# forward pass suffices.
while IFS= read -r f; do
    findings=$(awk -v FILE="$f" '
        function flag(msg) {
            if ($0 !~ /detlint: allow\(DL003\)/) {
                printf "%s:%d: %s\n", FILE, NR, msg
            }
        }
        {
            line = $0
            # strip comments so commented-out code never declares a name
            sub(/\/\/.*$/, "", line)
            if (line ~ /Hash(Map|Set)/) {
                name = ""
                if (match(line, /let +(mut +)?[a-z_][a-z0-9_]*/) &&
                    (line ~ /: *[^=;]*Hash(Map|Set)/ || line ~ /= *[A-Za-z:]*Hash(Map|Set) *::/)) {
                    name = substr(line, RSTART, RLENGTH)
                    sub(/^let +(mut +)?/, "", name)
                } else if (match(line, /[a-z_][a-z0-9_]* *: *&?(mut +)?(std::collections::)?Hash(Map|Set)</)) {
                    name = substr(line, RSTART, RLENGTH)
                    sub(/ *:.*$/, "", name)
                }
                if (name != "") { names[name] = 1 }
            } else if (match(line, /let +(mut +)?[a-z_][a-z0-9_]*/)) {
                # a later `let` shadowing the name with a non-hash type
                # retires it — the newest declaration wins
                name = substr(line, RSTART, RLENGTH)
                sub(/^let +(mut +)?/, "", name)
                delete names[name]
            }
            hit = ""
            for (nm in names) {
                if (line ~ ("(^|[^A-Za-z0-9_.])" nm "\\.(iter|into_iter|keys|values|drain)\\(")) {
                    hit = nm; break
                }
                if (line ~ ("for [^;]* in &?(mut +)?" nm "([^A-Za-z0-9_]|$)")) {
                    hit = nm; break
                }
                if (line ~ /^ *\.(iter|into_iter|keys|values|drain)\(/ &&
                    prev ~ ("(^|[^A-Za-z0-9_.])" nm " *$")) {
                    hit = nm; break
                }
            }
            if (hit != "") {
                flag("iteration over hash collection `" hit "` is allocation-order dependent; iterate an ordered collection or sort the result")
            }
            prev = line
        }
    ' "$f")
    if [ -n "$findings" ]; then
        while IFS= read -r finding; do
            report DL003 "${finding%%: *}" "${finding#*: }"
        done <<INNER
$findings
INNER
    fi
done <<EOF
$(find "$SRC" -name '*.rs' | sort)
EOF

# ---- DL004: unwrap/expect on I/O Results in store/ and eval/ --------------
# Statement-window scan: rustfmt splits `x.write_all(..).expect(..)`
# across lines, so a `.unwrap()`/`.expect(` counts as an I/O unwrap when
# the same line — or either of the two lines above it (one chained
# receiver + one I/O call) — names a filesystem/stream operation.
# Everything from `#[cfg(test)]` down is skipped: test code unwraps
# scratch-dir I/O freely, and this codebase keeps test modules last.
IO_RE='(std::)?fs::|File::|\.write_all\(|\.read_to_string\(|\.sync_all\(|\.flush\(|create_dir|remove_file|\.set_len\(|\.seek\(|\.rename\(|write_atomic'
while IFS= read -r f; do
    findings=$(awk -v FILE="$f" -v iore="$IO_RE" '
        /#\[cfg\(test\)\]/ { intest = 1 }
        {
            line = $0
            sub(/\/\/.*$/, "", line)
            io = (line ~ iore) ? 1 : 0
            if (!intest && line ~ /\.(unwrap|expect)\(/ && (io || prev_io || prev2_io)) {
                if ($0 !~ /detlint: allow\(DL004\)/) {
                    printf "%s:%d: unwrap/expect on an I/O Result in a durability layer; propagate the error or annotate the fail-stop\n", FILE, NR
                }
            }
            prev2_io = prev_io; prev_io = io
        }
    ' "$f")
    if [ -n "$findings" ]; then
        while IFS= read -r finding; do
            report DL004 "${finding%%: *}" "${finding#*: }"
        done <<INNER
$findings
INNER
    fi
done <<EOF
$(find "$SRC"/store "$SRC"/eval -name '*.rs' | sort)
EOF

if [ "$fail" -eq 0 ]; then
    echo "detlint: clean"
fi
exit "$fail"
